"""Roofline terms from a compiled (dry-run) executable.

    compute    = HLO_FLOPs_per_device / peak_FLOPs
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / link_bw

Sources: ``compiled.cost_analysis()`` supplies per-device FLOPs and bytes
(the executable is the SPMD-partitioned per-device module).
collective_bytes is parsed from the optimized HLO text: we sum the *result*
buffer sizes of every all-gather / all-reduce / reduce-scatter / all-to-all
/ collective-permute instruction (for reduce-scatter we count the operand
instead, since the result is the already-reduced shard).  ``-start`` fusion
variants are counted once (the matching ``-done`` is skipped).

Hardware model: TPU v5e — 197 bf16 TFLOP/s, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e5m2": 1, "f8e4m3fn": 1, "c128": 16,
}


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float          # per chip, bf16
    hbm_bw: float              # bytes/s per chip
    ici_bw: float              # bytes/s per link


HW_V5E = Hardware(name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9,
                  ici_bw=50e9)


def _shape_bytes(type_str: str) -> int:
    """'bf16[256,1024]{1,0}' -> byte size (tuples handled by the caller)."""
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", type_str.strip())
    if not m:
        return 0
    dtype, dims = m.group(1), m.group(2)
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|([a-z0-9]+\[[0-9,]*\][^ ]*))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Per-kind result-buffer bytes of collective ops in optimized HLO."""
    out: Dict[str, int] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        tuple_types, single_type, kind, startdone = (
            m.group(1), m.group(2), m.group(3), m.group(4))
        if startdone == "-done":
            continue   # counted at -start
        if tuple_types is not None:
            size = sum(_shape_bytes(t) for t in
                       re.findall(r"[a-z0-9]+\[[0-9,]*\]", tuple_types))
        else:
            size = _shape_bytes(single_type)
        out[kind] = out.get(kind, 0) + size
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: Dict[str, int]
    memory_per_device: float          # bytes (args+temps+outputs)
    model_flops: float                # 6·N·D global (N_active for MoE)
    hw: Hardware = HW_V5E

    @property
    def collective_total(self) -> int:
        return sum(self.collective_bytes.values())

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / self.hw.peak_flops

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / self.hw.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.collective_total / self.hw.ici_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Ideal-overlap model: step ≥ max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs × chips): how much compiled compute is
        'useful' (catches remat/dispatch waste; >1 ⇒ HLO under-counts e.g.
        because convs/attention aren't in 6·N·D)."""
        total = self.flops_per_device * self.n_chips
        return self.model_flops / total if total else float("nan")

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU under the ideal-overlap step-time model:
        useful FLOPs / (step_time × chips × peak)."""
        denom = self.step_time * self.n_chips * self.hw.peak_flops
        return self.model_flops / denom if denom else float("nan")

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.n_chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "hbm_gb_per_dev": self.memory_per_device / 2 ** 30,
            "model_gflops": self.model_flops / 1e9,
            "useful_ratio": self.useful_flops_ratio,
            "roofline_frac": self.roofline_fraction,
            "collectives": {k: v for k, v in self.collective_bytes.items()},
        }


def analyze_compiled(compiled, *, arch: str, shape: str, mesh_desc: str,
                     n_chips: int, model_flops: float,
                     hw: Hardware = HW_V5E) -> RooflineReport:
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):   # older jax: list of per-device dicts
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)
    mem_total = (getattr(mem, "argument_size_in_bytes", 0)
                 + getattr(mem, "output_size_in_bytes", 0)
                 + getattr(mem, "temp_size_in_bytes", 0))
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_desc, n_chips=n_chips,
        flops_per_device=float(cost.get("flops", 0.0)),
        bytes_per_device=float(cost.get("bytes accessed", 0.0)),
        collective_bytes=coll,
        memory_per_device=float(mem_total),
        model_flops=model_flops,
        hw=hw)


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D training; 2·N·D forward-only (prefill/decode)."""
    n = cfg.active_param_count_estimate
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
