"""Rounded Pallas flash-attention kernel family (kernels/flash_attention).

* **bit-exactness** — inside a single jit, each interpret-mode Pallas
  kernel (fwd / bwd-dq / bwd-dkv / decode) is bit-identical to its
  pure-jnp reference twin on ragged non-multiple shapes, GQA groupings,
  sliding windows and non-causal masks.  (Eager comparisons are NOT part
  of the contract: outside jit the two paths fuse differently and drift
  by 1-2 ulp, so every check here jits kernel and reference together.)
* **packed KV cache** — the decode kernel over binary8/e4m3 code words
  (decoded on load in-kernel) is bit-identical to the same kernel over
  the unpacked grid values, and to its reference.
* **policy wiring** — ``qattention``'s custom VJP under ``oracle=True``
  (reference twins) matches the kernel path bitwise, forward and grads.
* **eqs. (3)-(5)** — every SR site (qk / av / out / kv-store) draws
  unbiased bits with the paper's frac(1-frac)·ulp² variance, checked on
  kernel *outputs* at an exact interior point (Skv=1 collapses the
  softmax so each output element is a single rounding of X0).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rounding
from repro.core.rounding import IDENTITY, parse_spec
from repro.kernels import common
from repro.kernels import flash_attention as FA
from repro.models import attention as MA
from repro.precision import attention as PA
from repro.precision import policy as QP

KEY = jax.random.PRNGKey(13)
WORDS = common.derive_seed(KEY, 0)
SR8 = parse_spec("binary8-sr")
E4 = parse_spec("e4m3-sr")
SITE_TAGS = (QP.TAG_ATTN_QK, QP.TAG_ATTN_AV, QP.TAG_ATTN_OUT)
BLK = 16


def _seeds(n):
    return PA._site_seeds(WORDS, n, SITE_TAGS)


def _qkv(bh, bkv, sq, skv, dk, dv, seed=1, scale=1.0):
    kq, kk, kv_ = jax.random.split(jax.random.fold_in(KEY, seed), 3)
    return (jax.random.normal(kq, (bh, sq, dk), jnp.float32) * scale,
            jax.random.normal(kk, (bkv, skv, dk), jnp.float32) * scale,
            jax.random.normal(kv_, (bkv, skv, dv), jnp.float32) * scale)


def _eq(a, b, msg=""):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=msg)


# ------------------------------------------------------------- forward --
FWD_CASES = [
    # (h, kv, sq, skv, causal, window)
    (2, 2, 24, 24, True, 0),      # MHA, block-multiple
    (4, 2, 21, 37, True, 0),      # GQA + ragged non-multiple shapes
    (2, 1, 16, 40, True, 7),      # MQA, window smaller than a block
    (2, 2, 13, 13, False, 0),     # non-causal ragged
    (2, 2, 19, 19, False, 5),     # window + non-causal combo
]


@pytest.mark.parametrize("h,kv,sq,skv,causal,window", FWD_CASES)
def test_fwd_kernel_bitexact_vs_reference(h, kv, sq, skv, causal, window):
    q, k, v = _qkv(2 * h, 2 * kv, sq, skv, 8, 8)
    seeds = _seeds(2 * h)
    specs = FA.AttnSpecs(SR8, SR8, E4)
    kw = dict(scale=0.3, n_heads=h, n_kv=kv, causal=causal, window=window,
              q_block=BLK, kv_block=BLK)

    @jax.jit
    def both(q, k, v, seeds):
        return (FA.flash_fwd_p(q, k, v, seeds, specs, **kw),
                FA.flash_fwd_reference(q, k, v, seeds, specs, **kw))

    (o, m, l), (o_r, m_r, l_r) = both(q, k, v, seeds)
    _eq(o, o_r, "out")
    _eq(m, m_r, "m")
    _eq(l, l_r, "l")
    assert np.all(np.isfinite(np.asarray(o)))


def test_fwd_identity_specs_match_model_flash():
    """With identity specs the kernel computes plain flash attention —
    the jnp model implementation is the independent oracle."""
    B, Sq, H, KV, dk = 2, 27, 4, 2, 8
    kq, kk, kv_ = jax.random.split(KEY, 3)
    q4 = jax.random.normal(kq, (B, Sq, H, dk), jnp.float32)
    k4 = jax.random.normal(kk, (B, Sq, KV, dk), jnp.float32)
    v4 = jax.random.normal(kv_, (B, Sq, KV, dk), jnp.float32)
    scale = 1.0 / dk ** 0.5
    specs = FA.AttnSpecs(IDENTITY, IDENTITY, IDENTITY)
    q3 = q4.transpose(0, 2, 1, 3).reshape(B * H, Sq, dk)
    k3 = k4.transpose(0, 2, 1, 3).reshape(B * KV, Sq, dk)
    v3 = v4.transpose(0, 2, 1, 3).reshape(B * KV, Sq, dk)

    @jax.jit
    def run(q3, k3, v3):
        o3, _, _ = FA.flash_fwd_p(q3, k3, v3, _seeds(B * H), specs,
                                  scale=scale, n_heads=H, n_kv=KV,
                                  causal=True, window=5, q_block=BLK,
                                  kv_block=BLK)
        return o3

    out = run(q3, k3, v3).reshape(B, H, Sq, dk).transpose(0, 2, 1, 3)
    want = MA.flash_attention(q4, k4, v4, scale, causal=True, window=5,
                              q_block=BLK, kv_block=BLK)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-6)


# ------------------------------------------------------------ backward --
def test_bwd_kernels_bitexact_vs_reference():
    b, h, kv, sq, skv = 2, 2, 1, 17, 23
    bh, bkv = b * h, b * kv
    q, k, v = _qkv(bh, bkv, sq, skv, 8, 8, scale=0.5)
    do = jax.random.normal(jax.random.fold_in(KEY, 9), (bh, sq, 8),
                           jnp.float32)
    specs = FA.AttnSpecs(SR8, SR8, IDENTITY)
    w_qk = QP.fold_words(WORDS, QP.TAG_ATTN_QK)
    w_av = QP.fold_words(WORDS, QP.TAG_ATTN_AV)
    s_qk = QP.slice_words(w_qk, bh)
    seeds_dq = jnp.concatenate(
        [s_qk, QP.slice_words(QP.fold_words(w_qk, QP.SITE_DGRAD), bh)],
        axis=1)
    seeds_dkv = jnp.concatenate(
        [s_qk, QP.slice_words(QP.fold_words(w_qk, QP.SITE_WGRAD), bh),
         QP.slice_words(QP.fold_words(w_av, QP.SITE_DGRAD), bh)], axis=1)
    kw = dict(scale=0.25, n_heads=h, n_kv=kv, causal=True, window=0,
              q_block=BLK, kv_block=BLK)

    @jax.jit
    def both(q, k, v, do, seeds_f, seeds_dq, seeds_dkv):
        out, m, l = FA.flash_fwd_p(q, k, v, seeds_f, specs, **kw)
        d = jnp.sum(do * out, axis=-1)
        dq = FA.flash_bwd_dq_p(q, k, v, do, m, l, d, seeds_dq,
                               SR8, SR8, **kw)
        dq_r = FA.flash_bwd_dq_reference(q, k, v, do, m, l, d, seeds_dq,
                                         SR8, SR8, **kw)
        dk_, dv_ = FA.flash_bwd_dkv_p(q, k, v, do, m, l, d, seeds_dkv,
                                      SR8, SR8, SR8, **kw)
        dk_r, dv_r = FA.flash_bwd_dkv_reference(q, k, v, do, m, l, d,
                                                seeds_dkv, SR8, SR8, SR8,
                                                **kw)
        return dq, dq_r, dk_, dk_r, dv_, dv_r

    dq, dq_r, dk_, dk_r, dv_, dv_r = both(q, k, v, do, _seeds(bh),
                                          seeds_dq, seeds_dkv)
    _eq(dq, dq_r, "dq")
    _eq(dk_, dk_r, "dk")
    _eq(dv_, dv_r, "dv")
    for g in (dq, dk_, dv_):
        arr = np.asarray(g)
        assert np.all(np.isfinite(arr)) and np.any(arr != 0)


def test_qattention_oracle_matches_kernel_fwd_and_grads():
    """policy.oracle=True routes every call to the jnp reference twins;
    inside one jit that path must match the Pallas path bitwise — forward
    output and all three gradients (the audit-mode contract)."""
    pol_k = QP.PRESETS["e4m3-attn"]
    pol_o = dataclasses.replace(pol_k, oracle=True)
    B, Sq, H, KV, dk = 2, 11, 4, 2, 8
    kq, kk, kv_ = jax.random.split(jax.random.fold_in(KEY, 3), 3)
    q = jax.random.normal(kq, (B, Sq, H, dk), jnp.float32)
    k = jax.random.normal(kk, (B, Sq, KV, dk), jnp.float32)
    v = jax.random.normal(kv_, (B, Sq, KV, dk), jnp.float32)

    def loss(pol, q, k, v):
        o = PA.qattention(q, k, v, QP.QuantCtx(pol, WORDS), scale=0.35,
                          causal=True, q_block=BLK, kv_block=BLK)
        return jnp.sum(o * o), o

    @jax.jit
    def both(q, k, v):
        outs = []
        for pol in (pol_k, pol_o):
            (_, o), gs = jax.value_and_grad(
                lambda q_, k_, v_: loss(pol, q_, k_, v_),
                argnums=(0, 1, 2), has_aux=True)(q, k, v)
            outs.append((o,) + gs)
        return outs

    (o1, *g1), (o2, *g2) = both(q, k, v)
    _eq(o1, o2, "fwd")
    for a, b, name in zip(g1, g2, ("dq", "dk", "dv")):
        _eq(a, b, name)


# -------------------------------------------------------------- decode --
def _decode_setup(seed=5):
    B, KV, G, dk, smax = 2, 2, 2, 8, 40
    bkv = B * KV
    kq = jax.random.fold_in(KEY, seed)
    q = jax.random.normal(kq, (bkv, G, dk), jnp.float32)
    k_raw, v_raw = (jax.random.normal(jax.random.fold_in(kq, i),
                                      (bkv, smax, dk), jnp.float32)
                    for i in (1, 2))
    # cache values on the e4m3 grid: packing is then lossless, so the
    # packed and unpacked kernels see identical numbers
    grid = parse_spec("e4m3-rn")
    return q, grid(k_raw), grid(v_raw), _seeds(bkv)


@pytest.mark.parametrize("window", [0, 9])
def test_decode_kernel_bitexact_vs_reference(window):
    q, kf, vf, seeds = _decode_setup()
    specs = FA.AttnSpecs(SR8, SR8, E4)
    kw = dict(scale=0.3, window=window, kv_block=BLK)

    @jax.jit
    def both(q, kf, vf, seeds, length):
        return (FA.flash_decode_p(q, kf, vf, seeds, length, specs, **kw),
                FA.flash_decode_reference(q, kf, vf, seeds, length, specs,
                                          **kw))

    o, o_r = both(q, kf, vf, seeds, jnp.int32(27))
    _eq(o, o_r)
    assert np.all(np.isfinite(np.asarray(o)))


def test_decode_packed_cache_bitexact_vs_unpacked():
    q, kf, vf, seeds = _decode_setup()
    specs = FA.AttnSpecs(SR8, SR8, E4)
    kw = dict(scale=0.3, window=0, kv_block=BLK)

    @jax.jit
    def both(q, kf, vf, seeds, length):
        kp = common.pack_block(kf, "e4m3")
        vp = common.pack_block(vf, "e4m3")
        o_packed = FA.flash_decode_p(q, kp, vp, seeds, length, specs,
                                     kv_fmt="e4m3", **kw)
        o_packed_r = FA.flash_decode_reference(q, kp, vp, seeds, length,
                                               specs, kv_fmt="e4m3", **kw)
        o_float = FA.flash_decode_p(q, kf, vf, seeds, length, specs, **kw)
        return o_packed, o_packed_r, o_float, kp

    o_p, o_pr, o_f, kp = both(q, kf, vf, seeds, jnp.int32(33))
    assert np.asarray(kp).dtype == np.uint8
    _eq(o_p, o_pr, "packed kernel vs reference")
    _eq(o_p, o_f, "packed vs unpacked decode")


def test_decode_identity_matches_masked_softmax():
    q, kf, vf, _ = _decode_setup()
    specs = FA.AttnSpecs(IDENTITY, IDENTITY, IDENTITY)
    length, scale = 27, 0.3

    @jax.jit
    def run(q, kf, vf):
        return FA.flash_decode_p(q, kf, vf, _seeds(q.shape[0]),
                                 jnp.int32(length), specs, scale=scale,
                                 kv_block=BLK)

    out = np.asarray(run(q, kf, vf))
    s = np.einsum("bgd,bsd->bgs", np.asarray(q),
                  np.asarray(kf)[:, :length]) * scale
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    want = np.einsum("bgs,bsd->bgd", p, np.asarray(vf)[:, :length])
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-6)


# ----------------------------------------------- eqs. (3)-(5) per site --
X0 = 1.1            # binary8 interior point: ulp = 0.25, frac = 0.4


def _clt_tol(var, n, sigmas=4.0):
    return sigmas * np.sqrt(max(var, 1e-30) / n)


def _site_samples(site):
    """Kernel outputs shaped so each element is one independent rounding
    of the exact value X0 (Skv=1: softmax weight is exactly 1, so the
    qk / av / out sites each see X0 unperturbed)."""
    if site == "kv":
        x = jnp.full((4, 512, 2, 4), X0, jnp.float32)
        w = QP.fold_words(WORDS, QP.TAG_ATTN_KV)
        out = jax.jit(lambda x: PA.round_kv(x, SR8, w))(x)
        return np.asarray(out, np.float64).ravel()
    specs = {"qk": FA.AttnSpecs(SR8, IDENTITY, IDENTITY),
             "av": FA.AttnSpecs(IDENTITY, SR8, IDENTITY),
             "out": FA.AttnSpecs(IDENTITY, IDENTITY, SR8)}[site]
    if site == "qk":
        # s = scale·q·k = X0; with one key column, m (an output) IS the
        # rounded logit
        bh, sq, dv = 8, 2048, 8
        q = jnp.full((bh, sq, 1), X0, jnp.float32)
        k = jnp.ones((1, 1, 1), jnp.float32)
        v = jnp.ones((1, 1, dv), jnp.float32)
        n_heads, n_kv = bh, 1
    else:
        # s = 0 -> p = 1, l = 1: out = rounded(v) elementwise
        bh, sq, dv = 4, 512, 8
        q = jnp.zeros((bh, sq, 1), jnp.float32)
        k = jnp.ones((1, 1, 1), jnp.float32)
        v = jnp.full((1, 1, dv), X0, jnp.float32)
        n_heads, n_kv = bh, 1

    @jax.jit
    def run(q, k, v, seeds):
        return FA.flash_fwd_p(q, k, v, seeds, specs, scale=1.0,
                              n_heads=n_heads, n_kv=n_kv, causal=False)

    out, m, _ = run(q, k, v, _seeds(bh))
    return np.asarray(m if site == "qk" else out, np.float64).ravel()


@pytest.mark.parametrize("site", ["qk", "av", "out", "kv"])
def test_sr_site_unbiased_and_eq5_variance(site):
    err = _site_samples(site) - X0
    q = float(rounding.ulp(jnp.float32(X0), "binary8"))
    _, _, frac_a, _ = rounding.magnitude_decompose(
        jnp.float32(X0), rounding.get_format("binary8"))
    frac = float(frac_a)
    want_var = frac * (1.0 - frac) * q * q
    assert np.any(err != 0), site             # rounding actually happened
    # round the expected offsets to the same precision as the observed set
    # or exact-binary frac values fail the comparison on equal values
    assert set(np.round(np.unique(err) / q, 6)) <= \
        {round(-frac, 6), round(1.0 - frac, 6)}, site
    assert abs(err.mean()) < _clt_tol(want_var, err.size), (site, err.mean())
    assert abs(err.var() - want_var) < 0.08 * want_var, (site, err.var())


def test_sr_sites_draw_distinct_streams():
    """qk / av / out / kv folds must decorrelate: identical geometry, yet
    the round-up decisions differ between sites."""
    samples = {s: _site_samples(s)[:4096] > X0 for s in ("av", "out")}
    agree = np.mean(samples["av"] == samples["out"])
    assert 0.3 < agree < 0.7, agree
