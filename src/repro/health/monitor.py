"""In-step numeric-health telemetry (cheap jnp reductions inside jit).

Generalizes the paper's §3.2 stagnation diagnostics from the toy GD path
(`core/gd.rn_would_stagnate`, τ_k) to arbitrary model/optimizer pytrees:

* **deadband fraction** — the share of update coordinates with
  ``|t·ĝᵢ| < ulp(x̂ᵢ)/2``, i.e. the coordinates a round-to-nearest update
  would round away entirely (eq. 12's Scenario-2 predicate, evaluated via
  the half-quantum test instead of the exact RN comparison — one `ulp`
  decompose + one compare per element).  A deadband fraction near 1.0 is
  the paper's silent-stagnation signature: under RN the run has stopped
  moving even though gradients are non-zero.
* **saturation / underflow fractions** — coordinates whose gradient
  magnitude exceeds the active format's ``xmax`` (rounding saturates /
  overflows) or lies in ``(0, xmin_sub)`` (rounding flushes to zero).
  binary8's normal range tops out at 5.7e4, so these fire long before
  float32 itself misbehaves.
* **grad/update norms** and a **non-finite flag**.

All reductions are O(#params) elementwise work fused into the train step
— no extra HBM passes beyond reading tensors the step already touches.
The streak counters live in a :class:`HealthState` carried through the
train-step carry (`launch/steps.StepCarry`), so they survive jit and
checkpointing; the host-side policy decisions belong to
`health/watchdog.py`, which consumes the per-step metrics dict.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.formats import FPFormat
from repro.core.grids import Grid, get_grid


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Telemetry configuration.

    ``fmt`` names the low-precision *grid* the deadband / saturation /
    underflow accounting runs against — normally the grid of the active
    rounding policy (the one updates are actually rounded onto); any
    registered grid works (``"binary8"``, ``"fxp16.8"``, a shifted
    grid's name).  The thresholds feed the in-carry streak counters; the
    watchdog applies its own (host-side) thresholds on the raw fractions,
    so these only control what ``HealthState`` considers "a bad step".
    """

    fmt: str = "binary8"
    deadband_threshold: float = 0.9
    overflow_threshold: float = 0.0

    def grid(self) -> Grid:
        return get_grid(self.fmt)

    def format(self) -> FPFormat:
        return get_grid(self.fmt).fmt


def resolve_health(h: Any) -> Optional[HealthConfig]:
    """None | grid name | HealthConfig -> Optional[HealthConfig]."""
    if h is None:
        return None
    if isinstance(h, HealthConfig):
        return h
    return HealthConfig(fmt=get_grid(h).name)


class HealthState(NamedTuple):
    """Streak counters carried in the train-step carry (int32 scalars)."""

    deadband_streak: jax.Array    # consecutive steps with deadband ≥ thresh
    overflow_streak: jax.Array    # consecutive steps with saturation > thresh
    nonfinite_streak: jax.Array   # consecutive steps with non-finite grads


def init_health_state() -> HealthState:
    z = jnp.zeros((), jnp.int32)
    return HealthState(deadband_streak=z, overflow_streak=z,
                       nonfinite_streak=z)


def _float_leaves(*trees) -> Tuple[Tuple[jax.Array, ...], ...]:
    """Zip the float leaves of parallel pytrees (non-float leaves skipped)."""
    zipped = tuple(zip(*(jax.tree_util.tree_leaves(t) for t in trees)))
    return tuple(ls for ls in zipped
                 if all(hasattr(l, "dtype") for l in ls)
                 and jnp.issubdtype(ls[0].dtype, jnp.floating))


def health_metrics(params, grads, lr, cfg: HealthConfig) -> Dict[str, Any]:
    """One fused pass of telemetry reductions over (params, grads).

    ``lr`` is the stepsize ``t`` of the update ``t·ĝ`` the deadband test
    evaluates (the optimizer's learning rate).  Returns a dict of jnp
    scalars, all prefixed ``h_`` so they ride the train step's metrics
    dict into `TrainLoop` history without clashing with model metrics.
    """
    grid = cfg.grid()
    t = jnp.float32(lr)
    xmax = jnp.float32(grid.xmax)
    xmin = jnp.float32(grid.xmin_sub)
    total = 0
    dead = jnp.float32(0.0)
    sat = jnp.float32(0.0)
    under = jnp.float32(0.0)
    g_sq = jnp.float32(0.0)
    nonfin = jnp.float32(0.0)
    z = jnp.float32(0.0)
    for p, g in _float_leaves(params, grads):
        p32 = p.astype(jnp.float32).reshape(-1)
        g32 = g.astype(jnp.float32).reshape(-1)
        ag = jnp.abs(g32)
        fin = jnp.isfinite(g32)
        # non-finite grads would poison the norm; mask them out of the sum
        g_fin = jnp.where(fin, g32, 0.0)
        # one variadic reduce = ONE pass over the leaf for all five
        # counters (separate jnp.sum calls each cost a full memory pass on
        # CPU — measured 4.5x slower than this fused reduction):
        # deadband: |t·ĝ| below half the parameter's grid spacing — RN of
        # (x − t·ĝ) returns x (up to the ties-to-even boundary case).
        # The spacing comes from the grid (``Grid.ulp``), so fixed-point
        # and shifted grids deadband correctly too (uniform quantum /
        # carrier-scaled quantum), not just FP formats.
        d, s, u, q, nf = lax.reduce(
            ((t * ag < 0.5 * grid.ulp(p32)).astype(jnp.float32),
             (ag >= xmax).astype(jnp.float32),
             ((ag > 0) & (ag < xmin)).astype(jnp.float32),
             g_fin * g_fin,
             (~fin).astype(jnp.float32)),
            (z, z, z, z, z),
            lambda a, b: tuple(x + y for x, y in zip(a, b)), (0,))
        dead += d
        sat += s
        under += u
        g_sq += q
        nonfin += nf
        total += p.size
    finite = nonfin == 0
    n = jnp.float32(max(total, 1))
    g_norm = jnp.sqrt(g_sq)
    return {
        "h_deadband_frac": dead / n,
        "h_sat_frac": sat / n,
        "h_underflow_frac": under / n,
        "h_grad_norm": g_norm,
        # pre-rounding update magnitude ‖t·ĝ‖ (the quantity the paper's
        # Prop. 9/11 gradient floors bound from below)
        "h_update_norm": t * g_norm,
        "h_nonfinite": (~finite).astype(jnp.float32),
    }


def update_health(state: HealthState, metrics: Dict[str, Any],
                  cfg: HealthConfig) -> HealthState:
    """Advance the in-carry streak counters from one step's metrics."""

    def streak(s, bad):
        return jnp.where(bad, s + 1, 0).astype(jnp.int32)

    return HealthState(
        deadband_streak=streak(
            state.deadband_streak,
            metrics["h_deadband_frac"] >= cfg.deadband_threshold),
        overflow_streak=streak(
            state.overflow_streak,
            metrics["h_sat_frac"] > cfg.overflow_threshold),
        nonfinite_streak=streak(
            state.nonfinite_streak, metrics["h_nonfinite"] > 0),
    )


def observe_health(state: HealthState, params, grads, lr,
                   cfg: HealthConfig) -> Tuple[HealthState, Dict[str, Any]]:
    """Telemetry + streak update in one call (the train-step entry point)."""
    metrics = health_metrics(params, grads, lr, cfg)
    new_state = update_health(state, metrics, cfg)
    metrics["h_deadband_streak"] = new_state.deadband_streak
    metrics["h_overflow_streak"] = new_state.overflow_streak
    metrics["h_nonfinite_streak"] = new_state.nonfinite_streak
    return new_state, metrics
