"""QAdam — Adam with low-precision state and the paper's rounded update.

m and v are stored on configurable low-precision grids (stochastic rounding
keeps the small-update signal alive in the second moment exactly as it does
for the parameters); the final parameter update goes through the eq.-8
three-step rounding path, so signed-SRε biases the Adam step in a descent
direction just as for plain GD.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.gd import GDRounding
from repro.core.rounding import IDENTITY, RoundingSpec
from repro.optim import base


class QAdamState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any
    key: jax.Array


@dataclasses.dataclass(frozen=True)
class QAdam:
    lr: float
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    cfg: GDRounding = GDRounding()
    m_spec: RoundingSpec = IDENTITY
    v_spec: RoundingSpec = IDENTITY
    weight_decay: float = 0.0
    update_path: str = "jnp"   # "jnp" | "fused" | "fused_bits" (optim/base)

    def init(self, params, key: Optional[jax.Array] = None) -> QAdamState:
        key = jax.random.PRNGKey(0) if key is None else key
        zeros = lambda: jax.tree.map(jnp.zeros_like, params)
        return QAdamState(step=jnp.zeros((), jnp.int32), m=zeros(), v=zeros(),
                          key=key)

    def apply(self, params, grads, state: QAdamState,
              lr: Optional[Any] = None):
        t = self.lr if lr is None else lr
        step = state.step + 1
        km = base.leaf_keys(jax.random.fold_in(state.key, 0x6D), state.step, params)
        kv = base.leaf_keys(jax.random.fold_in(state.key, 0x76), state.step, params)

        def upd_m(m, g, k):
            return base.round_state(self.m_spec, self.b1 * m + (1 - self.b1) * g, k)

        def upd_v(v, g, k):
            return base.round_state(self.v_spec, self.b2 * v + (1 - self.b2) * g * g, k)

        new_m = jax.tree.map(upd_m, state.m, grads, km)
        new_v = jax.tree.map(upd_v, state.v, grads, kv)
        c1 = 1.0 - self.b1 ** step.astype(jnp.float32)
        c2 = 1.0 - self.b2 ** step.astype(jnp.float32)

        def direction(m, v, p):
            d = (m / c1) / (jnp.sqrt(v / c2) + self.eps)
            if self.weight_decay:
                d = d + self.weight_decay * p
            return d

        # the Adam direction plays the role of the gradient in eq. (8)
        directions = jax.tree.map(direction, new_m, new_v, params)
        new_params = base.tree_rounded_update(
            params, directions, t, self.cfg, state.key, state.step,
            update_path=self.update_path)
        return new_params, QAdamState(step=step, m=new_m, v=new_v,
                                      key=state.key)


def qadam(lr, b1=0.9, b2=0.999, eps=1e-8, cfg: GDRounding = GDRounding(),
          m_spec: RoundingSpec = IDENTITY, v_spec: RoundingSpec = IDENTITY,
          weight_decay=0.0, update_path: str = "jnp") -> QAdam:
    return QAdam(lr=lr, b1=b1, b2=b2, eps=eps, cfg=cfg, m_spec=m_spec,
                 v_spec=v_spec, weight_decay=weight_decay,
                 update_path=update_path)
