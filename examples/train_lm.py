"""End-to-end LM training with the paper's rounded optimizer.

CPU-sized default (reduced smollm-360m, ~0.1M params).  The same driver
trains the full architectures on a real mesh — e.g. a ~100M-param run:

  PYTHONPATH=src python examples/train_lm.py --full-100m --steps 300

Run:  PYTHONPATH=src python examples/train_lm.py
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.launch.train import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--full-100m", action="store_true",
                    help="train a ~100M-param smollm variant (slow on CPU)")
    ap.add_argument("--rounding", default="signed_sr_eps")
    args = ap.parse_args()

    if args.full_100m:
        # smollm-360m with 8 layers ≈ 100M params (embeddings dominate)
        import repro.configs as C
        cfg = dataclasses.replace(get_config("smollm-360m"), n_layers=8,
                                  remat="none", scan_layers=True)
        C.REGISTRY["smollm-100m"] = cfg
        run("smollm-100m", reduced=False, steps=args.steps, batch=4,
            seq=256, lr=0.02, rounding_kind=args.rounding, fmt="bfloat16",
            eps=0.1, ckpt_dir="/tmp/repro_ex_train100m")
    else:
        run("smollm-360m", reduced=True, steps=args.steps, batch=8,
            seq=128, lr=0.05, rounding_kind=args.rounding, fmt="bfloat16",
            eps=0.1, ckpt_dir="/tmp/repro_ex_train")


if __name__ == "__main__":
    main()
