"""§Perf hillclimbing driver: A/B a config/step variant against the
baseline on one (arch × shape) cell and print the roofline deltas.

Usage:
  PYTHONPATH=src python -m repro.launch.perf --arch tinyllama-1.1b \
      --shape train_4k --variant remat=dots
Variants (comma-separable):
  remat={full,dots,none}      activation-checkpoint policy
  attn={flash,naive}          attention implementation
  qblock=N / kvblock=N        flash attention block sizes
  mla_absorb={0,1}            absorbed-matmul MLA decode
  seqshard={0,1}              decode-cache sequence sharding over model
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json

from repro.configs import get_config


def apply_variant(cfg, variant: str):
    changes = {}
    for kv in variant.split(","):
        if not kv:
            continue
        k, v = kv.split("=")
        if k == "remat":
            changes["remat"] = v
        elif k == "attn":
            changes["attn_impl"] = v
        elif k == "qblock":
            changes["attn_q_block"] = int(v)
        elif k == "kvblock":
            changes["attn_kv_block"] = int(v)
        elif k == "mla_absorb":
            changes["mla_absorb"] = bool(int(v))
        else:
            raise ValueError(f"unknown variant key {k}")
    if "mla_absorb" in changes:
        mla = dataclasses.replace(cfg.mla, absorb=changes.pop("mla_absorb"))
        changes["mla"] = mla
    return dataclasses.replace(cfg, **changes)


def main():
    from repro.launch.dryrun import lower_cell
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="")
    ap.add_argument("--no-probe", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.variant:
        cfg = apply_variant(cfg, args.variant)
    _, report = lower_cell(args.arch, args.shape, probe=not args.no_probe,
                           cfg_override=cfg)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report.row(), f, indent=1, default=str)


if __name__ == "__main__":
    main()
