"""Pallas TPU kernel: blocked matmul with low-precision rounded output.

Models the paper's (8a): a gradient/activation GEMM whose *result* is stored
in the low-precision format (rounded by RN or SR).  MXU-shaped tiling:
(bm, bk) x (bk, bn) blocks accumulate into a float32 VMEM scratch across the
K grid dimension; on the last K step the accumulator is rounded and written
out.  Two flavours share all scaffolding (mode check, padding, geometry,
accumulate) and differ only in where the (bm, bn) bits tile for the
stochastic modes comes from: ``qmatmul_p`` reads an explicit uint32 HBM
operand (bit-exact oracle mode), ``qmatmul_prng_p`` generates it in-kernel
at emit time (the operand — 4 B per *output* element — vanishes from HBM).

Block sizes default to 128/256 multiples so the MXU (128x128) is saturated
and the working set (bm*bk + bk*bn + 2*bm*bn tiles) stays ≲ 2 MiB in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.formats import get_format
from repro.kernels import common


def _check_mode(mode: str) -> None:
    if mode == "signed_sr_eps":
        raise ValueError("signed_sr_eps is not supported for GEMM result "
                         "rounding (no bias-direction operand); use "
                         "'sr'/'sr_eps' or a deterministic mode")


def _pad_to(x, m0, m1):
    p0 = -(-x.shape[0] // m0) * m0 - x.shape[0]
    p1 = -(-x.shape[1] // m1) * m1 - x.shape[1]
    return jnp.pad(x, ((0, p0), (0, p1)))


def _geometry(a, b, bm, bn, bk):
    """Clamp block sizes, pad operands, derive the (i, j, k) grid."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm_, bn_, bk_ = min(bm, M), min(bn, N), min(bk, K)
    a_p = _pad_to(a, bm_, bk_)
    b_p = _pad_to(b, bk_, bn_)
    Mp, Kp = a_p.shape
    _, Np = b_p.shape
    k_steps = Kp // bk_
    grid = (Mp // bm_, Np // bn_, k_steps)
    return a_p, b_p, (M, N, Mp, Np), (bm_, bn_, bk_), k_steps, grid


def _accumulate(a_ref, b_ref, acc_ref):
    """Init-on-first-k + one (bm, bk) x (bk, bn) MXU step into the scratch."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)


def _qmatmul_kernel(a_ref, b_ref, bits_ref, o_ref, acc_ref,
                    *, fmt, mode, eps, k_steps):
    _accumulate(a_ref, b_ref, acc_ref)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _emit():
        bits = bits_ref[...] if mode in ("sr", "sr_eps") else None
        o_ref[...] = common.round_block(acc_ref[...], bits, fmt, mode, eps)


def qmatmul_p(a, b, bits, fmt, mode: str = "sr", eps: float = 0.0,
              *, bm: int = 256, bn: int = 256, bk: int = 256,
              interpret=None):
    """Rounded ``a @ b`` (result-rounding fidelity) as a Pallas kernel.

    a: (M, K) float32; b: (K, N) float32; bits: (M, N) uint32 (ignored for
    deterministic modes but must be supplied for a uniform signature).
    M, N, K are padded up to block multiples.  ``signed_sr_eps`` is
    rejected: result-rounding a GEMM has no bias-direction operand.
    """
    _check_mode(mode)
    fmt = get_format(fmt)
    if interpret is None:
        interpret = common.default_interpret()
    a_p, b_p, (M, N, Mp, Np), (bm_, bn_, bk_), k_steps, grid = \
        _geometry(a, b, bm, bn, bk)
    bits_p = _pad_to(bits, bm_, bn_)

    kern = functools.partial(_qmatmul_kernel, fmt=fmt, mode=mode, eps=eps,
                             k_steps=k_steps)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        interpret=interpret,
    )(a_p, b_p, bits_p)
    return out[:M, :N]


def _qmatmul_prng_kernel(seed_ref, a_ref, b_ref, o_ref, acc_ref,
                         *, fmt, mode, eps, k_steps, bm, bn, interpret):
    # program ids must be read at kernel top level: under interpret they are
    # not substituted inside pl.when sub-jaxprs (jax 0.4.x limitation)
    i, j = pl.program_id(0), pl.program_id(1)
    n_j = pl.num_programs(1)

    _accumulate(a_ref, b_ref, acc_ref)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _emit():
        if mode in ("sr", "sr_eps"):
            common.seed_kernel_prng(seed_ref, i * n_j + j,
                                    interpret=interpret)
            bits = common.kernel_bits(seed_ref, acc_ref.shape,
                                      row0=i * bm, col0=j * bn,
                                      interpret=interpret)
        else:
            bits = None
        o_ref[...] = common.round_block(acc_ref[...], bits, fmt, mode, eps)


def qmatmul_prng_p(a, b, seed, fmt, mode: str = "sr", eps: float = 0.0,
                   *, bm: int = 256, bn: int = 256, bk: int = 256,
                   interpret=None):
    """Rounded ``a @ b`` with in-kernel randomness (no bits operand).

    ``seed``: (2,) uint32 words (common.derive_seed) via SMEM scalar
    prefetch; the per-tile seed is (words, linearized (i, j) tile index).
    ``signed_sr_eps`` is rejected as in ``qmatmul_p``.
    """
    _check_mode(mode)
    fmt = get_format(fmt)
    if interpret is None:
        interpret = common.default_interpret()
    a_p, b_p, (M, N, Mp, Np), (bm_, bn_, bk_), k_steps, grid = \
        _geometry(a, b, bm, bn, bk)
    seed = jnp.asarray(seed, jnp.uint32).reshape(2)

    kern = functools.partial(_qmatmul_prng_kernel, fmt=fmt, mode=mode,
                             eps=eps, k_steps=k_steps, bm=bm_, bn=bn_,
                             interpret=interpret)
    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm_, bk_), lambda i, j, k, s: (i, k)),
                pl.BlockSpec((bk_, bn_), lambda i, j, k, s: (k, j)),
            ],
            out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k, s: (i, j)),
            scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        interpret=interpret,
    )(seed, a_p, b_p)
    return out[:M, :N]
