"""The PRF-free bf16-SR bit-trick (``sr_bittrick``).

``r = (bitcast(z, u32) + (b & 0xFFFF)) & 0xFFFF0000`` rounds a float32 to
bfloat16 stochastically: the round-up event is the carry out of the low 16
bits, i.e. the oracle event ``u < frac`` with the complemented uncentered
draw ``u = ((b & m) ^ m) · 2^-16``.  At r=16 on bfloat16 the fractional
position ``frac`` lies on the 2^-16 lattice, so the trick is *exactly*
unbiased (paper eq. 3), and the eq. 4–5 CLT machinery applies with the
same per-element variance bound as oracle SR.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rounding
from repro.core.rounding import parse_spec, round_to_format
from repro.core.schemes import format_spec_name, parse_spec_name
from repro.kernels import common, ops
from repro.kernels.sr_cast import sr_cast_p


# ------------------------------------------------------------- grammar ----
def test_spec_grammar_dash_and_underscore_spellings():
    for name in ("bf16-sr-bittrick", "bfloat16-sr_bittrick"):
        p = parse_spec_name(name)
        assert p.grid == "bfloat16" and p.scheme == "sr_bittrick"
        assert p.rand_bits == 16          # registry default
    # canonical emission round-trips through the parser
    p = parse_spec_name("bf16-sr-bittrick-r8")
    assert p.rand_bits == 8
    assert parse_spec_name(format_spec_name(*p)) == p
    s = parse_spec("e4m3-sr-bittrick")
    assert str(s) and parse_spec(str(s)) == s


# ----------------------------------------------- int-trick reference ------
def _copy_stochastic_np(target32, bits):
    """The published int-trick, verbatim in numpy: add 16 random mantissa
    bits, truncate to the bf16 boundary."""
    z = np.asarray(target32, np.float32).view(np.uint32)
    r = (z + (bits & np.uint32(0xFFFF))) & np.uint32(0xFFFF0000)
    return r.view(np.float32)


def test_bittrick_matches_int_reference_bit_for_bit():
    rng = np.random.default_rng(0)
    x = np.concatenate([
        rng.standard_normal(5000).astype(np.float32) * 10,
        rng.standard_normal(5000).astype(np.float32) * 1e-3,
        np.float32([0.0, -0.0, 1.0, -1.0, 3.0 + 2**-10, np.pi]),
    ])
    bits = rng.integers(0, 2**32, x.size, dtype=np.uint32)
    want = _copy_stochastic_np(x, bits)
    got = np.asarray(round_to_format(jnp.asarray(x), "bfloat16",
                                     "sr_bittrick", bits=jnp.asarray(bits),
                                     rand_bits=16))
    np.testing.assert_array_equal(got.view(np.uint32), want.view(np.uint32))


def test_bittrick_kernel_fast_path_matches_oracle():
    # the in-kernel int fast path (kernels/common.round_block) against the
    # jnp oracle, same explicit bits
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal(4096).astype(np.float32) * 3)
    bits = jnp.asarray(rng.integers(0, 2**32, 4096, dtype=np.uint32))
    got = sr_cast_p(x, bits, "bfloat16", "sr_bittrick", rand_bits=16)
    want = round_to_format(x, "bfloat16", "sr_bittrick", bits=bits,
                           rand_bits=16)
    np.testing.assert_array_equal(
        np.asarray(got).view(np.uint32), np.asarray(want).view(np.uint32))


def test_bittrick_preserves_grid_values_and_signed_zero():
    spec = parse_spec("bf16-sr-bittrick")
    on_grid = jnp.float32([0.0, -0.0, 1.0, -1.5, 2.0 ** -100, 340.0])
    on_grid = parse_spec("bfloat16-rn")(on_grid)   # snap to the grid
    out = spec(on_grid, key=jax.random.PRNGKey(3))
    np.testing.assert_array_equal(
        np.asarray(out).view(np.uint32), np.asarray(on_grid).view(np.uint32))


def test_bittrick_saturates_instead_of_nan():
    # adding mantissa bits can carry into the exponent: values near xmax
    # must saturate (default overflow) or go to exactly +/-inf, never NaN
    xmax = 3.3895314e38                      # bf16 xmax
    x = jnp.float32([xmax, -xmax, xmax * 0.999, np.inf, -np.inf])
    for _ in range(4):
        out = np.asarray(round_to_format(
            x, "bfloat16", "sr_bittrick",
            key=jax.random.PRNGKey(_), rand_bits=16))
        assert not np.isnan(out).any()
        assert (np.abs(out[:3]) <= xmax).all()
        assert out[3] == np.inf and out[4] == -np.inf


# ------------------------------------------------- eq. 3-5 statistics -----
def test_bittrick_unbiased_within_clt_bound():
    """Paper eqs. 3-5: SR roundoff is mean-zero with Var <= (ulp*frac*(1-
    frac)); the empirical mean over n draws must land inside the 4-sigma
    CLT band.  At r=16 on bfloat16 the draw lattice resolves frac exactly,
    so the bound is the oracle-SR one (no one-sided truncation bias)."""
    n = 200_000
    # one bf16 gap in the [1, 2) binade (7 mantissa bits -> ulp = 2^-7)
    lo, hi = np.float32(1.0), np.float32(1.0 + 2 ** -7)
    frac = 0.37
    x = jnp.full((n,), lo + frac * (hi - lo), jnp.float32)
    out = np.asarray(round_to_format(x, "bfloat16", "sr_bittrick",
                                     key=jax.random.PRNGKey(7),
                                     rand_bits=16))
    assert set(np.unique(out)) <= {lo, hi}
    p_up = (out == hi).mean()
    sigma = np.sqrt(frac * (1 - frac) / n)
    assert abs(p_up - frac) < 4 * sigma, (p_up, frac, sigma)
    mean_err = (out - np.asarray(x)).mean()
    assert abs(mean_err) < 4 * sigma * float(hi - lo)


def test_bittrick_low_rand_bits_one_sided_bias_bound():
    # with r < 16 the complemented draw truncates: bias is one-sided,
    # bounded by 2^-r ulp (the registry's documented bound)
    n = 100_000
    lo, hi = np.float32(1.0), np.float32(1.0 + 2 ** -7)
    frac = 0.37
    x = jnp.full((n,), lo + frac * (hi - lo), jnp.float32)
    out = np.asarray(round_to_format(x, "bfloat16", "sr_bittrick",
                                     key=jax.random.PRNGKey(9),
                                     rand_bits=8))
    p_up = (out == hi).mean()
    sigma = np.sqrt(frac * (1 - frac) / n)
    # round-up probability quantized to the 2^-8 lattice, never above frac
    assert frac - 2 ** -8 - 4 * sigma <= p_up <= frac + 4 * sigma


def test_bittrick_prng_kernel_runs_and_is_deterministic():
    x = jnp.asarray(np.random.default_rng(4)
                    .standard_normal(2048).astype(np.float32))
    key = jax.random.PRNGKey(11)
    a = ops.sr_cast_prng(x, key, "bfloat16", "sr_bittrick", rand_bits=16)
    b = ops.sr_cast_prng(x, key, "bfloat16", "sr_bittrick", rand_bits=16)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # outputs are on the bf16 grid
    np.testing.assert_array_equal(
        np.asarray(a), np.asarray(parse_spec("bfloat16-rn")(a)))
