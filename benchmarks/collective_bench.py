"""Rounded-collective and gradient-accumulation microbenchmarks.

Two row families feeding ``BENCH_kernels.json`` (and therefore the CI
perf gate) alongside the kernel rows:

* **accumulation throughput** — one microbatch-gradient add on a 1M-element
  tree through each registered carry (fp32 exact, bf16-RN, bf16-SR,
  compensated bf16-SR, binary8-SR).  Wall-clocks are CPU software-emulation
  overhead; the derived columns are slowdown ratios vs the fp32 add of the
  same shape (higher is worse — the perf-gate quantities).
* **wire encode + wire-byte model** — the codec quantization cost of a 1M
  payload (the compute each participant adds per hop), plus derived-only
  rows for the reduce-scatter wire-byte model: an fp32 ring all-reduce
  moves ``2·(p-1)/p·4`` B/elt per participant; the rounded reduce-scatter
  topology moves the same pattern at codec width (int8/binary8/e4m3: 1 B →
  ratio 0.25, bf16: 2 B → ratio 0.5) — EXPERIMENTS.md §Rounded distributed
  training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist import codecs as codecs_lib
from repro.dist.collectives import wire_bytes
from repro.optim.accumulate import get_accumulator

# wire-byte model at the production participant count
WIRE_P = 8


def _time_many(fns, iters):
    from benchmarks.kernel_bench import _time_many as tm
    return tm(fns, iters)


def rows(n: int = 1 << 20, iters: int = 20):
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (n,), jnp.float32) * 1e-3
    acc_presets = ["fp32", "bf16-rn", "bf16-sr", "bf16-sr-kahan",
                   "binary8-sr"]

    def make_add(preset):
        acc = get_accumulator(preset)
        words = acc.step_words(key, 0)

        @jax.jit
        def add(t, g_):
            return acc.add(t, {"g": g_}, words, 1).total["g"]
        total0 = acc.init({"g": g})
        return lambda: add(total0, g)

    adds = [make_add(p) for p in acc_presets]

    # codec encode cost of one 1M-element wire payload
    codec = codecs_lib.get_wire_codec("int8-sr")
    words = codecs_lib.wire_words(key, 0)

    @jax.jit
    def encode(g_, w_):
        bits = codecs_lib.codec_bits(codec, w_, g_.shape)
        return codec.quantize(g_, bits=bits)

    times = _time_many(adds + [lambda: encode(g, words)], iters)
    us_acc, us_enc = times[:-1], times[-1]
    melt = n / 1e6
    us_fp32 = us_acc[0]

    out = [("collective/accum_fp32_us_per_Melt", us_fp32 / melt, 1.0,
            iters)]
    out += [
        (f"collective/accum_{p.replace('-', '_')}_us_per_Melt",
         us / melt, us / us_fp32, iters)
        for p, us in zip(acc_presets[1:], us_acc[1:])]
    out.append(("collective/wire_encode_int8_sr_us_per_Melt",
                us_enc / melt, us_enc / us_fp32, iters))

    # derived-only wire-byte model rows (us == 0: excluded from the gate);
    # see collectives.wire_bytes for the ring model
    tree = {"g": g}
    for name in (None, "int8-sr", "e4m3-sr", "bf16-sr"):
        total, ratio = wire_bytes(tree, name, WIRE_P)
        tag = (name or "fp32").replace("-", "_")
        out.append((f"collective/wire_{tag}_B_per_elt", 0.0, total / n, 0))
        out.append((f"collective/wire_{tag}_traffic_ratio_vs_fp32", 0.0,
                    ratio, 0))
    # the quantized all-reduce ships fp32 partial means on the gather
    # phase — the contrast that motivates the reduce-scatter topology
    total_ar, ratio_ar = wire_bytes(tree, "int8-sr", WIRE_P,
                                    topology="allreduce")
    out.append(("collective/wire_int8_sr_allreduce_B_per_elt", 0.0,
                total_ar / n, 0))
    out.append(("collective/wire_int8_sr_allreduce_ratio_vs_fp32", 0.0,
                ratio_ar, 0))
    return out
