"""gemma-7b — GeGLU, head_dim=256, MHA (kv=16), huge vocab.
[arXiv:2403.08295; hf]  28L d_model=3072 16H d_ff=24576 vocab=256000."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    ffn_act="geglu",
    pos="rope",
    tie_embeddings=True,
)
