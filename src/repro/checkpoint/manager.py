"""Atomic, asynchronous, topology-elastic, *verified* checkpointing.

Fault-tolerance contract (designed for preemptible 1000-node fleets):

* **Atomicity** — a checkpoint is staged into ``step_<n>.tmp`` and
  ``os.rename``d into place only when fully written; a crash mid-save can
  never corrupt the latest restorable state.
* **Asynchrony** — ``save(blocking=False)`` takes a cheap *device-side*
  snapshot (one ``jnp.copy`` per leaf, guarding against later donation or
  deletion) and returns; the device→host transfer AND serialization both
  run on the background writer thread, so the step path only enqueues.
  ``wait()`` fences before exit, and an ``atexit`` hook fences
  automatically so an async save in flight at interpreter exit is never
  silently dropped.
* **Elasticity** — leaves are stored as *full* (unsharded) host arrays with
  the pytree structure; ``restore`` re-places them under whatever sharding
  the *current* mesh prescribes, so a job can resume on a smaller/larger
  topology after node loss (pod-loss drill in tests/test_checkpoint.py).
* **Compactness** — with a ``fmt`` grid configured, float32 leaves whose
  values already live on that rounding grid (rounded params, low-precision
  moment carries) are stored as packed uint8/uint16 grid codes — the same
  (sign | exponent | mantissa) layout as ``kernels/common.pack_block``,
  re-derived here in pure numpy.  Packing is **self-validating**: each
  leaf is encoded, decoded, and compared bitwise on the writer thread;
  any leaf that does not round-trip exactly (fp32 state, off-grid values)
  is stored raw.  Restore is therefore bit-exact *unconditionally*.
  Leaves are distributed over several ``leaves*.npz`` shard files
  (size-balanced) so large checkpoints stream/fsck in parallel.
* **Completeness** — the data-pipeline step and PRNG state checkpoint with
  the model, so restart is bit-exact (stochastic rounding uses counter-based
  keys; see optim/base.py).
* **Integrity** — per-file SHA-256 checksums are recorded in ``meta.json``;
  ``restore()`` with no explicit step verifies and falls back to the newest
  *intact* checkpoint, so a garbled ``leaves.npz`` (disk bit-rot, torn
  write on a dying node) costs at most ``save_every`` steps, not the run.
  Writes retry transient I/O errors with capped exponential backoff.
  Checkpoints written by the pre-packing format (single ``leaves.npz``,
  no ``format`` field) remain restorable.
"""
from __future__ import annotations

import atexit
import hashlib
import json
import os
import pickle
import shutil
import threading
import time
import weakref
from typing import Any, Callable, List, Optional

import jax
import numpy as np

# files whose checksums guard a *legacy* checkpoint's integrity (v2
# checkpoints list every file explicitly in meta["sha256"])
_HASHED_FILES = ("leaves.npz", "treedef.pkl")

_FORMAT_V2 = 2

# transient-I/O retry schedule: attempts, initial delay, cap (seconds)
_WRITE_ATTEMPTS = 3
_WRITE_DELAY = 0.05
_WRITE_DELAY_CAP = 1.0


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


# ---------------------------------------------------------------------------
# Pure-numpy packed grid codes — the same generic (sign | biased-exponent |
# mantissa) layout as kernels/common.pack_block/unpack_block, usable on the
# writer thread without touching jax.  ldexp is an exact power-of-two
# scaling in float64, and every grid significand fits 24 bits, so encode
# and decode are exact wherever the jax codec is.
# ---------------------------------------------------------------------------
def _grid_pack_params(grid_name: str):
    from repro.core.grids import get_grid
    from repro.kernels.common import pack_spec
    fmt = get_grid(grid_name).fmt
    ebits, mbits, width, has_nf = pack_spec(grid_name)
    return fmt, ebits, mbits, width, has_nf


def pack_np(x: np.ndarray, grid_name: str) -> np.ndarray:
    """float32 values on ``grid_name``'s grid -> packed uint8/uint16 codes."""
    fmt, ebits, mbits, width, has_nf = _grid_pack_params(grid_name)
    x = np.asarray(x, np.float32)
    sign = np.signbit(x).astype(np.uint32)
    mag = np.abs(x)
    finite = np.isfinite(x)
    mag_f = np.where(finite, mag, np.float32(fmt.xmax))
    is_sub = mag_f < np.float32(fmt.xmin)
    with np.errstate(divide="ignore"):
        bits32 = mag_f.view(np.uint32)
        raw_exp = ((bits32 >> 23) & 0xFF).astype(np.int64)
        e_norm = raw_exp - 127
    e = np.where(is_sub, np.int64(fmt.emin), e_norm)
    q = np.ldexp(mag_f.astype(np.float64), mbits - e)
    m = q.astype(np.uint32) & np.uint32((1 << mbits) - 1)
    field = np.where(is_sub, np.uint32(0),
                     (e - fmt.emin + 1).astype(np.uint32))
    code = (sign << np.uint32(ebits + mbits)) | (field << np.uint32(mbits)) | m
    if has_nf:
        nf_field = np.uint32((1 << ebits) - 1)
        m_nf = np.where(np.isnan(x), np.uint32((1 << mbits) - 1),
                        np.uint32(0))
        code_nf = (sign << np.uint32(ebits + mbits)) \
            | (nf_field << np.uint32(mbits)) | m_nf
        code = np.where(finite, code, code_nf)
    return code.astype(np.uint8 if width == 1 else np.uint16)


def unpack_np(codes: np.ndarray, grid_name: str) -> np.ndarray:
    """Inverse of :func:`pack_np` — exact float32 grid values."""
    fmt, ebits, mbits, _, has_nf = _grid_pack_params(grid_name)
    c = np.asarray(codes).astype(np.uint32)
    sign = (c >> np.uint32(ebits + mbits)) & np.uint32(1)
    field = (c >> np.uint32(mbits)) & np.uint32((1 << ebits) - 1)
    m = c & np.uint32((1 << mbits) - 1)
    is_sub = field == 0
    e = np.where(is_sub, np.int64(fmt.emin),
                 field.astype(np.int64) - 1 + fmt.emin)
    sig = np.where(is_sub, m, m + np.uint32(1 << mbits)).astype(np.float64)
    with np.errstate(over="ignore"):    # non-finite codes overwritten below
        out = np.ldexp(sig, e - mbits).astype(np.float32)
    out = np.where(sign == 1, -out, out)
    # -0.0: sign applied via copysign for the zero codes
    out = np.where((sig == 0) & (sign == 1), np.float32(-0.0), out)
    if has_nf:
        nf = field == (1 << ebits) - 1
        inf = np.where(sign == 1, -np.inf, np.inf).astype(np.float32)
        out = np.where(nf, np.where(m == 0, inf, np.float32(np.nan)), out)
    return out


def resolve_ckpt_grid(fmt: Optional[str]) -> Optional[str]:
    """Validate a ``--ckpt-fmt`` value and return the canonical grid name.

    Accepts any canonical spec name (``"bf16-sr"`` — the scheme part is
    ignored, packing is a lossless re-encoding of values already on the
    grid), a bare grid name (``"e4m3"``), or ``"fp32"``/``"none"``/None
    for no packing.  Raises on unknown names or grids too wide to pack —
    the import-time validation contract of the launch CLI.
    """
    if fmt is None:
        return None
    from repro.core.schemes import parse_spec_name
    parsed = parse_spec_name(fmt if "-" in fmt else f"{fmt}-rn") \
        if fmt not in ("fp32", "none") else None
    if parsed is None or parsed.grid is None:
        return None
    from repro.kernels.common import pack_spec
    pack_spec(parsed.grid)           # raise early on unpackable grids
    return parsed.grid


def _atexit_fence(ref):
    mgr = ref()
    if mgr is not None:
        mgr._join()          # flush, never raise during interpreter exit


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 fmt: Optional[str] = None, shards: int = 4):
        self.directory = directory
        self.keep = keep
        self.fmt = resolve_ckpt_grid(fmt)
        self.shards = max(1, int(shards))
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        # weakref so the fence doesn't pin the manager (and its directory
        # handle) alive for the whole process; gc'd managers cost nothing
        atexit.register(_atexit_fence, weakref.ref(self))

    # ------------------------------------------------------------------ save
    def _snapshot(self, tree: Any) -> Any:
        """Device-side copy of every array leaf — O(bytes) on-device, no
        host transfer; later donation/overwrite of the live buffers cannot
        corrupt the pending write."""
        import jax.numpy as jnp

        def snap(x):
            if isinstance(x, jax.Array):
                return jnp.copy(x)
            if isinstance(x, np.ndarray):
                return np.array(x, copy=True)
            return x

        return jax.tree.map(snap, tree)

    def _to_host(self, tree: Any) -> Any:
        """Gather snapshot leaves to host numpy (writer-thread side)."""
        return jax.tree.map(
            lambda x: np.asarray(jax.device_get(x))
            if isinstance(x, (jax.Array, np.ndarray)) else x, tree)

    def _encode_leaf(self, arr):
        """(stored_array, grid_name_or_None): pack a float32 leaf to grid
        codes iff the round-trip is bitwise exact (self-validating)."""
        if (self.fmt is None or not isinstance(arr, np.ndarray)
                or arr.dtype != np.float32 or arr.size == 0):
            return arr, None
        try:
            codes = pack_np(arr, self.fmt)
            back = unpack_np(codes, self.fmt)
        except Exception:
            return arr, None
        if np.array_equal(back.view(np.uint32), arr.view(np.uint32)):
            return codes, self.fmt
        return arr, None

    @staticmethod
    def _shard_name(k: int) -> str:
        # shard 0 keeps the legacy name: external tooling (fault
        # injection's corrupt_checkpoint) targets "leaves.npz"
        return "leaves.npz" if k == 0 else f"leaves.{k}.npz"

    def _assign_shards(self, leaves) -> List[int]:
        """Greedy size-balanced shard index per leaf."""
        n_shards = min(self.shards, max(1, len(leaves)))
        loads = [0] * n_shards
        assign = [0] * len(leaves)
        order = sorted(range(len(leaves)),
                       key=lambda i: -getattr(leaves[i], "nbytes", 0))
        for i in order:
            k = loads.index(min(loads))
            assign[i] = k
            loads[k] += max(getattr(leaves[i], "nbytes", 0), 1)
        return assign

    def save(self, step: int, tree: Any, *, blocking: bool = False,
             extra: Optional[dict] = None):
        """Checkpoint a pytree.  Non-blocking saves snapshot device-side
        and hand off; the host transfer happens on the writer thread."""
        self.wait()
        snap_tree = self._snapshot(tree)

        def write_once(host_tree):
            tmp = os.path.join(self.directory, f"step_{step}.tmp")
            final = os.path.join(self.directory, f"step_{step}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            leaves, treedef = jax.tree_util.tree_flatten(host_tree)
            stored, leaf_meta = [], []
            for arr in leaves:
                enc, packed = self._encode_leaf(arr)
                stored.append(enc)
                leaf_meta.append({"packed": packed})
            assign = self._assign_shards(stored)
            n_shards = (max(assign) + 1) if assign else 1
            for i, k in enumerate(assign):
                leaf_meta[i]["file"] = self._shard_name(k)
            for k in range(n_shards):
                np.savez(os.path.join(tmp, self._shard_name(k)),
                         **{f"leaf_{i}": l for i, l in enumerate(stored)
                            if assign[i] == k})
            with open(os.path.join(tmp, "treedef.pkl"), "wb") as f:
                pickle.dump(treedef, f)
            hashed = [self._shard_name(k) for k in range(n_shards)] \
                + ["treedef.pkl"]
            digests = {name: _sha256(os.path.join(tmp, name))
                       for name in hashed}
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump({"step": step, "extra": extra or {},
                           "format": _FORMAT_V2, "sha256": digests,
                           "leaves": leaf_meta}, f)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)
            self._gc()

        def write():
            try:
                host_tree = self._to_host(snap_tree)
            except BaseException as e:
                self._error = e
                return
            delay = _WRITE_DELAY
            for attempt in range(_WRITE_ATTEMPTS):
                try:
                    write_once(host_tree)
                    return
                except OSError as e:       # transient I/O: retry w/ backoff
                    if attempt == _WRITE_ATTEMPTS - 1:
                        self._error = e
                        return
                    time.sleep(delay)
                    delay = min(delay * 2, _WRITE_DELAY_CAP)
                except BaseException as e:  # surfaced on next save/wait
                    self._error = e
                    return

        if blocking:
            write()
            self._raise_pending()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def _join(self):
        """Fence the background write without raising (safe in handlers)."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def wait(self):
        self._join()
        self._raise_pending()

    def _raise_pending(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(self._list_steps())
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def _list_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def all_steps(self):
        # fence first: a step mid-write must not be invisible to callers
        # deciding whether durable state exists (TrainLoop snapshot release)
        self._join()
        return self._list_steps()

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def verify(self, step: int) -> bool:
        """True iff step's files are present and match recorded checksums.

        v2 checkpoints hash every shard file; legacy checkpoints hash
        ``leaves.npz``/``treedef.pkl``, and pre-checksum checkpoints (no
        "sha256" in meta) pass on existence alone, so old run directories
        stay restorable.
        """
        path = os.path.join(self.directory, f"step_{step}")
        try:
            with open(os.path.join(path, "meta.json")) as f:
                meta = json.load(f)
        except (OSError, ValueError):
            return False
        digests = meta.get("sha256")
        names = sorted(digests) if digests else _HASHED_FILES
        for name in names:
            fpath = os.path.join(path, name)
            if not os.path.exists(fpath):
                return False
            if digests is not None and _sha256(fpath) != digests.get(name):
                return False
        return True

    def _load(self, step: int, shardings: Optional[Any]):
        path = os.path.join(self.directory, f"step_{step}")
        with open(os.path.join(path, "treedef.pkl"), "rb") as f:
            treedef = pickle.load(f)
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        if meta.get("format", 1) >= _FORMAT_V2:
            leaf_meta = meta["leaves"]
            files = {}
            leaves = []
            for i, entry in enumerate(leaf_meta):
                fname = entry["file"]
                if fname not in files:
                    files[fname] = np.load(os.path.join(path, fname),
                                           allow_pickle=True)
                arr = files[fname][f"leaf_{i}"]
                if entry.get("packed"):
                    arr = unpack_np(arr, entry["packed"])
                leaves.append(arr)
        else:
            data = np.load(os.path.join(path, "leaves.npz"),
                           allow_pickle=True)
            leaves = [data[f"leaf_{i}"] for i in range(len(data.files))]
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s) if s is not None else x,
                tree, shardings)
        return step, tree, meta.get("extra", {})

    def restore(self, step: Optional[int] = None,
                shardings: Optional[Any] = None):
        """Load a checkpoint; optionally re-place leaves onto ``shardings``
        (a pytree of jax.sharding.Sharding matching the checkpointed tree —
        this is the elastic-resume path).  Returns (step, tree, extra).

        With no explicit ``step``, checksum-verifies candidates newest-first
        and restores the newest *intact* one; an explicit ``step`` that
        fails verification raises ``IOError`` (the caller asked for that
        exact state — silently substituting another would be worse).
        """
        self.wait()
        if step is not None:
            if not self.verify(step):
                raise IOError(
                    f"checkpoint step_{step} in {self.directory} is "
                    f"corrupt or incomplete")
            return self._load(step, shardings)
        for s in reversed(self._list_steps()):
            if self.verify(s):
                return self._load(s, shardings)
        raise FileNotFoundError(
            f"no intact checkpoints in {self.directory}")
