"""Mamba2 (SSD) block — chunked matmul formulation, TPU-native.

State-space recurrence with scalar-per-head decay (Mamba2's SSD):

    h_t = a_t · h_{t-1} + dt_t · B_t ⊗ x_t          (h: per-head (P, N))
    y_t = C_t · h_t + D ⊙ x_t

Training uses the chunk-parallel form (Mamba-2 paper §6): within a chunk of
length ``Lc`` the output is an (Lc × Lc) decay-masked attention-like matmul
(MXU-friendly); across chunks a short ``lax.scan`` carries the (H, P, N)
state.  Decode is the O(1) single-step recurrence with a rolling conv state.
This is the TPU adaptation: no CUDA selective-scan kernel, but the same
FLOP structure mapped onto dense matmuls.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.precision import policy as QP


class SSMCache(NamedTuple):
    conv: jax.Array    # (B, W-1, conv_dim) rolling conv input window
    state: jax.Array   # (B, H, P, N) SSD state


def _dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.state_dim   # x + B + C (single group)
    return d_inner, H, conv_dim


def ssm_init(key, cfg):
    s = cfg.ssm
    d = cfg.d_model
    d_inner, H, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        # order: [z (d_inner) | x (d_inner) | B (N) | C (N) | dt (H)]
        "in_proj": L.dense_init(ks[0], d, 2 * d_inner + 2 * s.state_dim + H),
        "conv_w": jax.random.normal(ks[1], (s.conv_width, conv_dim),
                                    jnp.float32) * 0.2,
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.zeros((d_inner,), jnp.float32),
        "out_proj": L.dense_init(ks[2], d_inner, d),
    }


def _split_proj(proj, cfg):
    s = cfg.ssm
    d_inner, H, _ = _dims(cfg)
    z, xs, Bmat, Cmat, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + s.state_dim,
               2 * d_inner + 2 * s.state_dim], axis=-1)
    return z, xs, Bmat, Cmat, dt


def _causal_conv(u, w, b):
    """u: (B, S, C); w: (W, C) depthwise causal conv."""
    W = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + u.shape[1], :] * w[i] for i in range(W))
    return jax.nn.silu(out + b)


def _ssd_chunked(xh, Bm, Cm, dt, A_log, chunk: int):
    """Chunk-parallel SSD.

    xh: (B, S, H, P); Bm/Cm: (B, S, N); dt: (B, S, H) (softplus'ed).
    Returns y: (B, S, H, P) and final state (B, H, P, N).
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    nc = S // chunk
    a = -jnp.exp(A_log)[None, None, :] * dt            # log decay (B, S, H) <= 0
    # chunked views
    def ch(t):
        return t.reshape(Bsz, nc, chunk, *t.shape[2:])
    xc, bc, cc, dtc, ac = ch(xh), ch(Bm), ch(Cm), ch(dt), ch(a)
    cum = jnp.cumsum(ac, axis=2)                       # (B, nc, Lc, H)

    # intra-chunk: scores[t,s] = C_t·B_s * exp(cum_t - cum_s) * dt_s, t >= s
    scores = jnp.einsum("bctn,bcsn->bcts", cc, bc)     # (B,nc,Lc,Lc)
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Lc,Lc,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    gate = jnp.where(mask[None, None, :, :, None], jnp.exp(decay), 0.0)
    attn = scores[..., None] * gate * dtc[:, :, None, :, :]  # (B,nc,t,s,H)
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", attn, xc)

    # inter-chunk: scan over chunk states
    # state contribution of chunk: sum_s exp(cum_last - cum_s)*dt_s B_s x_s
    last = cum[:, :, -1:, :]                           # (B,nc,1,H)
    w_in = jnp.exp(last - cum) * dtc                   # (B,nc,Lc,H)
    chunk_state = jnp.einsum("bcsh,bcsn,bcshp->bchpn", w_in, bc, xc)
    chunk_decay = jnp.exp(last[:, :, 0, :])            # (B,nc,H)

    def scan_fn(h, inp):
        cs, cd = inp                                   # (B,H,P,N), (B,H)
        h_new = h * cd[:, :, None, None] + cs
        return h_new, h                                # emit state *before* chunk

    h0 = jnp.zeros((Bsz, H, P, N), xh.dtype)
    h_final, h_prevs = jax.lax.scan(
        scan_fn,
        h0,
        (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)              # (B,nc,H,P,N)

    y_inter = jnp.einsum("bcth,bctn,bchpn->bcthp",
                         jnp.exp(cum), cc, h_prevs)
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y, h_final


def ssm_apply(params, x, cfg, cache: Optional[SSMCache] = None,
              return_state: bool = False, quant=None
              ) -> Tuple[jax.Array, Optional[SSMCache]]:
    """x: (B, S, D).  Decode path (cache given) expects S == 1.  ``quant``
    routes the in/out projections (the block's weight GEMMs) through the
    rounded-GEMM path; the SSD state recurrence itself is elementwise /
    activation-only contractions and stays fp32 (allowlisted)."""
    s = cfg.ssm
    d_inner, H, conv_dim = _dims(cfg)
    B_, S, D = x.shape
    dtype = x.dtype
    proj = L.qdense(x, params["in_proj"], quant, QP.TAG_SSM_IN)
    z, xs, Bm, Cm, dt = _split_proj(proj, cfg)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])

    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)

    if cache is None:
        conv_out = _causal_conv(conv_in, params["conv_w"], params["conv_b"])
        xs, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + s.state_dim],
                               axis=-1)
        xh = xs.reshape(B_, S, H, s.head_dim).astype(jnp.float32)
        y, h_final = _ssd_chunked(xh, Bm.astype(jnp.float32),
                                  Cm.astype(jnp.float32), dt,
                                  params["A_log"], min(s.chunk, S))
        new_cache = None
        if return_state:    # prefill: final state + rolling conv window
            new_cache = SSMCache(conv=conv_in[:, -(s.conv_width - 1):, :],
                                 state=h_final)
    else:
        # roll the conv window: window = [cache.conv, conv_in]
        window = jnp.concatenate([cache.conv, conv_in], axis=1)
        W = s.conv_width
        conv_out = sum(window[:, i:i + 1, :] * params["conv_w"][i]
                       for i in range(W))
        conv_out = jax.nn.silu(conv_out + params["conv_b"])
        xs, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + s.state_dim],
                               axis=-1)
        xh = xs.reshape(B_, 1, H, s.head_dim).astype(jnp.float32)
        a = jnp.exp(-jnp.exp(params["A_log"])[None, None, :] * dt)  # (B,1,H)
        dBx = jnp.einsum("bsh,bsn,bshp->bhpn", dt, Bm.astype(jnp.float32), xh)
        h = cache.state * a[:, 0, :, None, None] + dBx
        y = jnp.einsum("bsn,bhpn->bshp", Cm.astype(jnp.float32), h)
        new_cache = SSMCache(conv=window[:, 1:, :], state=h)

    y = y + params["D"][None, None, :, None] * xh
    y = y.reshape(B_, S, d_inner).astype(dtype)
    y = L.rms_norm(y * jax.nn.silu(z), params["norm"])
    return L.qdense(y, params["out_proj"], quant, QP.TAG_SSM_OUT), new_cache


def init_ssm_cache(cfg, batch: int, dtype=jnp.float32,
                   n_layers: Optional[int] = None) -> SSMCache:
    s = cfg.ssm
    d_inner, H, conv_dim = _dims(cfg)
    nl = n_layers if n_layers is not None else cfg.n_layers
    return SSMCache(
        conv=jnp.zeros((nl, batch, s.conv_width - 1, conv_dim), dtype),
        state=jnp.zeros((nl, batch, H, s.head_dim, s.state_dim), dtype))
