"""rwkv6-7b ("Finch") — attention-free, data-dependent decay.
[arXiv:2404.05892; hf]  32L d_model=4096 d_ff=14336 vocab=65536.
Sub-quadratic: runs the long_500k cell."""
from repro.configs.base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,            # 4096 / head_dim 64
    n_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    ffn_act="relu_sq",
    pos="none",
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, chunk=128),
    subquadratic=True,
)
