"""The assigned input-shape grid and per-(arch × shape) applicability.

LM transformer shapes (seq_len × global_batch):
  train_4k     4,096 × 256   -> train_step
  prefill_32k  32,768 × 32   -> prefill (forward + cache emission)
  decode_32k   32,768 × 128  -> serve_step (1 new token, 32k cache)
  long_500k    524,288 × 1   -> serve_step; sub-quadratic archs only

Skips (documented in DESIGN.md §4): ``long_500k`` is skipped for pure
full-attention architectures (MLA included — compressed KV but O(L²)
scores).  Every assigned arch has a decode path, so no decode skips.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str           # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

SHAPE_NAMES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def applicable(cfg, shape_name: str) -> Tuple[bool, Optional[str]]:
    """(runs?, skip_reason)."""
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, ("full-attention architecture: 500k decode needs "
                       "sub-quadratic sequence mixing (DESIGN.md §4)")
    return True, None


def grid():
    """All 40 (arch, shape) cells with applicability."""
    from repro.configs import ARCH_NAMES, get_config
    cells = []
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        for shape in SHAPE_NAMES:
            ok, reason = applicable(cfg, shape)
            cells.append({"arch": arch, "shape": shape, "runs": ok,
                          "skip_reason": reason})
    return cells
