"""Packed low-precision checkpoints (checkpoint/manager.py format 2).

Grid-coded leaves: float32 leaves whose values sit on a rounding grid are
re-encoded as uint8/uint16 exponent/mantissa codes (lossless — the writer
round-trips every leaf and falls back to raw on any mismatch), sharded
across several ``leaves*.npz`` files, written fully off the step path
(device snapshot on the caller thread, ``device_get`` + encode + fsync on
the writer thread), and restored bit-exactly — unsharded, onto an SPMD
mesh, and across process boundaries."""
import os
import subprocess
import sys
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.checkpoint import CheckpointManager
from repro.checkpoint.manager import pack_np, resolve_ckpt_grid, unpack_np
from repro.core.rounding import parse_spec
from repro.data import ShardedPipeline, make_token_pipeline
from repro.train import TrainLoop, TrainLoopConfig

_SRC = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))

GRIDS = ["bfloat16", "e4m3", "binary8", "binary16", "fxp8.4"]


# ------------------------------------------------------- numpy codecs -----
@pytest.mark.parametrize("grid", GRIDS)
def test_pack_np_roundtrip_is_bit_exact_on_grid(grid):
    snap = parse_spec(f"{grid}-rn")
    rng = np.random.default_rng(3)
    vals = np.concatenate([
        rng.standard_normal(4000).astype(np.float32) * 4,
        rng.standard_normal(2000).astype(np.float32) * 1e-3,  # subnormals
        np.float32([0.0, -0.0, 1.0, -1.0, 1e30, -1e30]),      # saturation
    ])
    on_grid = np.asarray(snap(jnp.asarray(vals)))
    codes = pack_np(on_grid, grid)
    assert codes.dtype in (np.uint8, np.uint16)
    back = unpack_np(codes, grid)
    np.testing.assert_array_equal(back.view(np.uint32),
                                  on_grid.view(np.uint32))


def test_resolve_ckpt_grid_grammar():
    assert resolve_ckpt_grid("bf16-sr") == "bfloat16"
    assert resolve_ckpt_grid("e4m3") == "e4m3"
    assert resolve_ckpt_grid("fp32") is None
    assert resolve_ckpt_grid(None) is None
    with pytest.raises(Exception):
        resolve_ckpt_grid("not-a-grid")


# --------------------------------------------------- manager round-trip ---
@pytest.mark.parametrize("grid", ["bfloat16", "e4m3"])
def test_packed_save_restore_bit_exact_mixed_tree(tmp_path, grid):
    snap = parse_spec(f"{grid}-rn")
    rng = np.random.default_rng(5)
    tree = {
        "on_grid": snap(jnp.asarray(
            rng.standard_normal(3000).astype(np.float32))),
        "off_grid": jnp.asarray(                 # stays raw float32
            rng.standard_normal(100).astype(np.float32) + 1e-5),
        "codes16": jnp.asarray(rng.integers(0, 2 ** 16, 64), jnp.uint16),
        "codes8": jnp.asarray(rng.integers(0, 2 ** 8, 64), jnp.uint8),
        "step": jnp.int32(9),
    }
    mgr = CheckpointManager(str(tmp_path), fmt=f"{grid}-sr", shards=3)
    mgr.save(9, tree, blocking=True)
    assert mgr.verify(9)

    import json
    with open(tmp_path / "step_9" / "meta.json") as f:
        meta = json.load(f)
    assert meta["format"] == 2
    packed = [e["packed"] for e in meta["leaves"] if e.get("packed")]
    assert packed == [grid]                      # exactly the on-grid leaf
    # the packed leaf really shrank on disk: grid codes are 1-2 bytes/elt
    sizes = {e["file"] for e in meta["leaves"]}
    assert len(sizes) > 1                        # actually sharded

    step, back, _ = CheckpointManager(str(tmp_path)).restore()
    assert step == 9
    for k in tree:
        a, b = np.asarray(tree[k]), np.asarray(back[k])
        assert a.dtype == b.dtype, k
        np.testing.assert_array_equal(
            np.atleast_1d(a).view(np.uint8), np.atleast_1d(b).view(np.uint8))


def test_off_grid_float_leaves_never_lose_bits(tmp_path):
    # a leaf with values off the bf16 grid must be stored raw, even when a
    # packing fmt is configured: packing is opt-in per leaf by losslessness
    x = jnp.asarray(np.float32([1.0 + 2 ** -20, np.pi, 1e-40]))
    mgr = CheckpointManager(str(tmp_path), fmt="bf16-sr")
    mgr.save(1, {"x": x}, blocking=True)
    _, back, _ = mgr.restore()
    np.testing.assert_array_equal(np.asarray(back["x"]).view(np.uint32),
                                  np.asarray(x).view(np.uint32))


# ------------------------------------- satellite: async off the step path -
def test_device_get_runs_on_writer_thread_not_caller(tmp_path, monkeypatch):
    """The satellite-1 regression: ``save(blocking=False)`` used to call
    ``jax.device_get`` on the caller (step) thread; it must now happen on
    the background writer after a cheap device-side snapshot."""
    import repro.checkpoint.manager as mgr_mod
    seen = {}
    real = mgr_mod.CheckpointManager._to_host

    def spy(self, tree):
        seen["thread"] = threading.current_thread()
        return real(self, tree)

    monkeypatch.setattr(mgr_mod.CheckpointManager, "_to_host", spy)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(2, {"x": jnp.zeros(100_000)}, blocking=False)
    mgr.wait()
    assert seen["thread"] is not threading.main_thread()
    assert mgr.verify(2)


def test_async_save_snapshots_before_caller_mutates(tmp_path):
    # the device/host snapshot is taken synchronously in save(): in-place
    # mutation of a host leaf right after save() must not leak into the
    # checkpoint (the old device_get-in-caller code got this by accident;
    # the snapshot code must keep it)
    x = np.ones(50_000, np.float32)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"x": x}, blocking=False)
    x[:] = -1.0
    mgr.wait()
    _, back, _ = mgr.restore()
    np.testing.assert_array_equal(np.asarray(back["x"]),
                                  np.ones(50_000, np.float32))


# ------------------------------------------- TrainLoop bit-exact resume ---
def _packed_setup(ckpt_dir, total):
    src = make_token_pipeline(vocab_size=50, seq_len=4, global_batch=2)
    pipe = ShardedPipeline(src)
    snap = parse_spec("bfloat16-rn")
    w0 = snap(jnp.ones((4,), jnp.float32))

    @jax.jit
    def step_fn(state, batch):
        w, n = state
        tgt = batch["tokens"][0, :4].astype(jnp.float32) / 50.0
        g = w - tgt
        # keep w on the bf16 grid so the checkpoint leaves actually pack
        return (snap(w - 0.1 * g), n + 1), {"loss": jnp.sum(g * g)}

    cfg = TrainLoopConfig(total_steps=total, checkpoint_every=5,
                          checkpoint_dir=str(ckpt_dir), log_every=5,
                          checkpoint_fmt="bf16-sr", checkpoint_shards=2)
    return step_fn, pipe, (w0, jnp.zeros((), jnp.int32)), cfg


def test_trainloop_packed_resume_bit_exact(tmp_path):
    # clean 20-step run
    step_fn, pipe, state, cfg = _packed_setup(tmp_path / "clean", 20)
    clean = TrainLoop(step_fn, pipe, state, cfg)
    clean.run()

    # interrupted at step 10, resumed by a fresh loop over the same dir
    step_fn, pipe, state, cfg = _packed_setup(tmp_path / "ck", 10)
    TrainLoop(step_fn, pipe, state, cfg).run()
    import json
    with open(tmp_path / "ck" / "step_10" / "meta.json") as f:
        meta = json.load(f)
    assert any(e.get("packed") == "bfloat16" for e in meta["leaves"])

    step_fn, pipe, state, cfg = _packed_setup(tmp_path / "ck", 20)
    resumed = TrainLoop(step_fn, pipe, state, cfg)
    out = resumed.run()
    assert out["final_step"] == 20
    np.testing.assert_array_equal(np.asarray(resumed.state[0]),
                                  np.asarray(clean.state[0]))


# ---------------------------------------------------- sharded (mesh) ------
_MESH_CODE = """
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                           + os.environ.get("XLA_FLAGS", ""))
import sys
import numpy as np
import jax, jax.numpy as jnp
jax.config.update('jax_default_prng_impl', 'threefry2x32')
jax.config.update('jax_threefry_partitionable', True)
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointManager
from repro.core.rounding import parse_spec

d, phase = sys.argv[1], sys.argv[2]
mesh = Mesh(np.array(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
sh = NamedSharding(mesh, P(None, "model"))
rep = NamedSharding(mesh, P())
x = parse_spec("bfloat16-rn")(
    jnp.arange(512., dtype=jnp.float32).reshape(4, 128) / 7.0)
x = jax.device_put(x, sh)
if phase == "save":
    mgr = CheckpointManager(d, fmt="bf16-sr", shards=3)
    mgr.save(4, {"x": x, "n": jnp.int32(7)}, blocking=True)
    assert mgr.verify(4)
else:
    step, tree, _ = CheckpointManager(d).restore(
        shardings={"x": sh, "n": rep})
    assert step == 4
    r = tree["x"]
    assert r.sharding.is_equivalent_to(sh, r.ndim), r.sharding
    np.testing.assert_array_equal(np.asarray(r).view(np.uint32),
                                  np.asarray(x).view(np.uint32))
    assert int(tree["n"]) == 7
print("OK")
"""


@pytest.mark.slow
def test_packed_checkpoint_sharded_resume_across_processes(tmp_path):
    """Save a mesh-sharded, grid-packed checkpoint in one process; restore
    it in another directly onto the mesh layout, bit-exactly."""
    env = dict(os.environ, PYTHONPATH=_SRC + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    for phase in ("save", "restore"):
        r = subprocess.run(
            [sys.executable, "-c", _MESH_CODE, str(tmp_path), phase],
            env=env, capture_output=True, text=True, timeout=300)
        assert r.returncode == 0, (phase, r.stderr)
        assert "OK" in r.stdout


# ------------------------------------------- packed optimizer state -------
def test_qadam_packed_state_checkpoints_bit_exact(tmp_path):
    """The uint8/uint16 moment-code leaves of a packed QAdam state ride
    through the checkpoint raw and resume bit-exactly."""
    from repro.core import gd
    from repro.optim.adam import qadam
    opt = qadam(lr=0.01, cfg=gd.make_config("bfloat16", "rn", "sr", "sr"),
                m_spec=parse_spec("bfloat16-sr"),
                v_spec=parse_spec("e4m3-sr"),
                update_path="fused", moments_packed=True)
    params = {"w": jnp.asarray(np.random.default_rng(0)
                               .standard_normal(300).astype(np.float32))}
    grads = {"w": jnp.full((300,), 0.2, jnp.float32)}
    state = opt.init(params, jax.random.PRNGKey(1))
    params, state = opt.apply(params, grads, state)

    mgr = CheckpointManager(str(tmp_path), fmt="bf16-sr")
    mgr.save(1, {"params": params, "opt": state}, blocking=True)
    _, back, _ = CheckpointManager(str(tmp_path)).restore()

    p2a, s2a = opt.apply(params, grads, state)
    p2b, s2b = opt.apply(back["params"], grads, back["opt"])
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), (p2a, s2a), (p2b, s2b))
