"""Evaluators for the paper's convergence bounds (sec. 4).

These are used (a) in tests, to check the *implementation* of rounded GD
against the theory (monotonicity under the stated conditions, rate bounds),
and (b) in the benchmarks, to draw the Theorem-2 bound curve of Figure 3.
"""
from __future__ import annotations

import numpy as np

from repro.core.formats import get_format


def exact_rate_bound(L: float, t: float, k, x0_dist: float):
    """Theorem 2: f(x_k) − f* ≤ 2L‖x0 − x*‖² / (4 + L·t·k)."""
    k = np.asarray(k, np.float64)
    return 2.0 * L * x0_dist ** 2 / (4.0 + L * t * k)


def u_upper_bound(a: float, c: float) -> float:
    """Prop. 3 / Lemma 4 precision requirement: u ≤ a / (c + 4a + 4)."""
    return a / (c + 4.0 * a + 4.0)


def stepsize_bound(L: float, fmt) -> float:
    """Rounded-GD stepsize requirement t ≤ 1 / (L (1+2u)²)."""
    u = get_format(fmt).u
    return 1.0 / (L * (1.0 + 2.0 * u) ** 2)


def gradient_floor_general(a: float, c: float, fmt, n: int) -> float:
    """Lemma 4 eq. (24): ‖∇f‖ ≥ a⁻¹(2 + 4u + √a)·√n·c·u."""
    u = get_format(fmt).u
    return (2.0 + 4.0 * u + np.sqrt(a)) * np.sqrt(n) * c * u / a


def gradient_floor_sr(a: float, c: float, fmt, n: int, condition: int = 14) -> float:
    """Theorem 6 gradient floors: eq. (33) for condition (14), (35) for (15)."""
    u = get_format(fmt).u
    if condition == 14:
        return (2.0 + np.sqrt(a)) * np.sqrt(n) * c * u / a
    if condition == 15:
        return 3.0 * np.sqrt(n) * c * u / a
    raise ValueError("condition must be 14 or 15")


def sr_rate_bound(L: float, t: float, k, chi: float, a: float,
                  condition: int = 14):
    """Theorem 6: E[f(x_k) − f*] ≤ 2Lχ² / (4 + L·t·k·(1−2a))  (cond. 14)
    or (1−2a²) (cond. 15)."""
    k = np.asarray(k, np.float64)
    shrink = (1.0 - 2.0 * a) if condition == 14 else (1.0 - 2.0 * a ** 2)
    return 2.0 * L * chi ** 2 / (4.0 + L * t * k * shrink)


def sr_eps_rate_bound(L: float, t: float, k, chi: float, a: float,
                      b: float, condition: int = 14):
    """Corollary 7: as Theorem 6 but with (1 + 2b − 2a) [or (1 + 2b − 2a²)],
    0 < b ≤ 2εu — the SRε acceleration term."""
    k = np.asarray(k, np.float64)
    shrink = (1.0 + 2.0 * b - 2.0 * a) if condition == 14 else (1.0 + 2.0 * b - 2.0 * a ** 2)
    return 2.0 * L * chi ** 2 / (4.0 + L * t * k * shrink)


def b_upper_bound(eps: float, fmt) -> float:
    """Corollary 7 / Lemma 1: 0 < b ≤ 2εu."""
    return 2.0 * eps * get_format(fmt).u


def stagnation_monotonicity_floor_sr(c: float, fmt, n: int, t: float,
                                     x_norm: float, condition: int = 14) -> float:
    """Prop. 9 gradient floors (51)/(52) for SR under stagnation."""
    u = get_format(fmt).u
    if condition == 14:
        return c * u * np.sqrt(n) / (1 - c * u) + (u / t) * np.sqrt(1.0 / (1 - c * u)) * x_norm
    return (u / t) * x_norm


def stagnation_monotonicity_floor_signed(c: float, fmt, n: int, t: float,
                                         x_norm: float, eps: float,
                                         condition: int = 14) -> float:
    """Prop. 11 gradient floors (62)/(63) for signed-SRε under stagnation."""
    u = get_format(fmt).u
    if condition == 14:
        return (c * u * np.sqrt(n) / (1 - c * u)
                + (u / t) * np.sqrt((1 + 2 * eps) / (1 - c * u)) * x_norm)
    return (u / t) * np.sqrt(1 + 2 * eps) * x_norm
