"""Rounding-scheme registry + the canonical RoundingSpec name grammar.

The paper's central object — a rounding scheme defined by its round-up
probability on a grid — is first-class here: a :class:`RoundingScheme`
declares its

* ``p_up(frac, fy, sign_x, eps, sign_v)`` rule — the probability of
  rounding the magnitude away from zero, the unified rule every scheme
  in the paper (and the follow-up papers) reduces to;
* **randomness budget** — ``"none"`` (deterministic), ``"uniform"``
  (a uniform draw ``u`` compared against ``p_up``; SR/SRε/signed-SRε),
  or ``"comparison"`` (SR 2.0, arXiv 2410.10517: a *single* ``r``-bit
  comparison draw ``u = b·2^-r`` with no half-ulp centering — cheaper
  than centered few-random-bits SR and biased *away from zero* by at
  most ``2^-r`` ulp instead of ``2^-(r+1)`` toward nearest);
* theoretical **bias bound** per rounded element (documentation string,
  asserted by the CLT tests in tests/test_new_schemes.py).

Everything importable here is jax-free at module import time (``jnp`` is
imported lazily inside the ``p_up`` bodies), so pure-policy consumers —
`health/watchdog`'s import-time ladder validation — can parse and
validate spec names without dragging in jax.

Canonical spec names
--------------------

One string grammar serves `precision/policy`, `dist/codecs`,
`optim/accumulate`, `health/watchdog` and the launch CLI::

    <grid>-<scheme>[-e<eps>][-r<rand_bits>][-inf]

    "binary8-sr"        SR on the binary8 (E5M2) grid
    "bf16-ssr-e0.4"     signed-SRε, ε=0.4, on bfloat16
    "fxp16.8-sr2"       SR 2.0 on the 16.8 fixed-point grid
    "e4m3-sr-r8"        few-random-bits SR, 8 bits/element
    "binary8-rn-inf"    RN with overflow to ±inf instead of saturation

``"fp32"``/``"none"`` name the identity (no rounding).  Suffix defaults
come from the scheme (``sr_eps``/``ssr`` default to the paper's ε=0.1;
``sr2`` defaults to its single 8-bit comparison draw), so every legacy
name — wire codecs' ``"bf16-ssr"``, accumulate's ``"bf16-sr"`` — parses
to the exact spec its private table used to build.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Dict, NamedTuple, Optional, Tuple

from repro.core import grids as _grids

RAND_BITS_CHOICES = (8, 16, 32)


# --------------------------------------------------------------- schemes --
@dataclasses.dataclass(frozen=True)
class RoundingScheme:
    """One rounding scheme: the unified magnitude rule + its randomness.

    ``p_up(frac, fy, sign_x, eps, sign_v)`` operates on the grid
    decomposition (`grids.Grid.decompose`): ``frac`` ∈ [0, 1) is the
    fractional position between grid neighbours, ``fy`` the integer floor
    significand (for ties-to-even parity), ``sign_x`` the sign of the
    value *in grid domain*, ``sign_v`` the sign of the bias-direction
    operand (signed-SRε only).
    """

    name: str
    randomness: str        # "none" | "uniform" | "comparison" | "bittrick"
    p_up: Callable
    needs_v: bool = False
    default_eps: float = 0.0
    default_rand_bits: int = 32
    bias_bound: str = "0"

    @property
    def stochastic(self) -> bool:
        return self.randomness != "none"

    @property
    def p_up_is_frac(self) -> bool:
        """Whether ``p_up == frac`` identically (SR / SR 2.0 / the bf16
        bit-trick) — enables the kernels' pure-SR fast path (the frac==0
        fix-up is a no-op)."""
        return self.name in ("sr", "sr2", "sr_bittrick")


def _p_sr(frac, fy, sign_x, eps, sign_v):
    return frac


def _p_sr_eps(frac, fy, sign_x, eps, sign_v):
    import jax.numpy as jnp
    return jnp.minimum(frac + eps, 1.0)


def _p_signed_sr_eps(frac, fy, sign_x, eps, sign_v):
    import jax.numpy as jnp
    return jnp.clip(frac - sign_x * sign_v * eps, 0.0, 1.0)


def _p_rn(frac, fy, sign_x, eps, sign_v):
    import jax.numpy as jnp
    fy_odd = (fy.astype(jnp.int32) & 1).astype(frac.dtype)
    return jnp.where(frac > 0.5, 1.0, jnp.where(frac < 0.5, 0.0, fy_odd))


def _p_rz(frac, fy, sign_x, eps, sign_v):
    import jax.numpy as jnp
    return jnp.zeros_like(frac)


def _p_ra(frac, fy, sign_x, eps, sign_v):
    import jax.numpy as jnp
    return jnp.ones_like(frac)


def _p_rd(frac, fy, sign_x, eps, sign_v):   # toward -inf
    import jax.numpy as jnp
    return jnp.where(sign_x < 0, 1.0, 0.0).astype(frac.dtype)


def _p_ru(frac, fy, sign_x, eps, sign_v):   # toward +inf
    import jax.numpy as jnp
    return jnp.where(sign_x > 0, 1.0, 0.0).astype(frac.dtype)


_SCHEMES: Dict[str, RoundingScheme] = {}
# "sr-bittrick" lets the two-word spelling ("bf16-sr-bittrick") name the
# scheme through the dash grammar; the canonical name keeps an underscore
# so format_spec_name round-trips through the single-token path.
_ALIASES: Dict[str, str] = {"ssr": "signed_sr_eps",
                            "sr-bittrick": "sr_bittrick"}


def register_scheme(s: RoundingScheme) -> None:
    _SCHEMES[s.name] = s


def get_scheme(name_or_scheme) -> RoundingScheme:
    """Resolve a scheme by name/alias (or pass through a RoundingScheme)."""
    if isinstance(name_or_scheme, RoundingScheme):
        return name_or_scheme
    name = _ALIASES.get(str(name_or_scheme), str(name_or_scheme))
    try:
        return _SCHEMES[name]
    except KeyError as exc:
        raise ValueError(f"unknown rounding scheme {name_or_scheme!r}; "
                         f"known: {scheme_names()}") from exc


def scheme_names() -> Tuple[str, ...]:
    return tuple(sorted(_SCHEMES))


for _s in (
    RoundingScheme("rn", "none", _p_rn,
                   bias_bound="0 (ties-to-even); deadbands below ulp/2"),
    RoundingScheme("rz", "none", _p_rz, bias_bound="-sign(x)·ulp"),
    RoundingScheme("ra", "none", _p_ra, bias_bound="+sign(x)·ulp"),
    RoundingScheme("rd", "none", _p_rd, bias_bound="-ulp"),
    RoundingScheme("ru", "none", _p_ru, bias_bound="+ulp"),
    RoundingScheme("sr", "uniform", _p_sr,
                   bias_bound="0 (Def. 1, eq. 3); ≤ 2^-(r+1)·ulp with an "
                              "r-bit centered draw"),
    RoundingScheme("sr_eps", "uniform", _p_sr_eps, default_eps=0.1,
                   bias_bound="sign(x)·ε·ulp (Def. 2)"),
    RoundingScheme("signed_sr_eps", "uniform", _p_signed_sr_eps,
                   needs_v=True, default_eps=0.1,
                   bias_bound="-sign(v)·ε·ulp (Def. 3, a descent direction)"),
    # SR 2.0 (arXiv 2410.10517): p_up == frac like SR, but the draw is a
    # single r-bit comparison u = b·2^-r with NO half-ulp centering —
    # P(round up) = ceil(frac·2^r)/2^r ≥ frac, so the residual bias is in
    # [0, 2^-r)·ulp *away from zero* (one-sided), vs the centered r-bit
    # draw's two-sided ≤ 2^-(r+1)·ulp.  Cheapest stochastic scheme: one
    # comparison, r=8 default → 1/4 of the PRF traffic of 32-bit SR.
    RoundingScheme("sr2", "comparison", _p_sr, default_rand_bits=8,
                   bias_bound="[0, 2^-r)·ulp away from zero (one-sided)"),
    # PRF-free bf16 bit-trick SR (the `copy_stochastic_` idiom): add r
    # random mantissa bits to the float32 word, mask to the top 16 bits.
    # The carry out of the low bits IS the round-up event, so the oracle
    # draw is the *complemented* uncentered uniform u = (b XOR (2^r-1))·2^-r
    # — P(round up) = ceil(frac·2^r)/2^r, and on the bfloat16 grid (where
    # frac is an exact multiple of 2^-16 for r=16) that equals frac
    # exactly: unbiased SR per eq. 3 with zero PRF-to-uniform conversion.
    RoundingScheme("sr_bittrick", "bittrick", _p_sr, default_rand_bits=16,
                   bias_bound="0 on bfloat16 at r=16 (frac ∈ 2^-16·Z); "
                              "[0, 2^-r)·ulp one-sided elsewhere"),
):
    register_scheme(_s)


DETERMINISTIC_MODES = tuple(n for n in ("rn", "rz", "ra", "rd", "ru")
                            if n in _SCHEMES)
STOCHASTIC_MODES = tuple(n for n, s in sorted(_SCHEMES.items())
                         if s.stochastic)
ALL_MODES = DETERMINISTIC_MODES + STOCHASTIC_MODES


# ---------------------------------------------------------------- parser --
class ParsedSpec(NamedTuple):
    """The jax-free result of :func:`parse_spec_name`.

    ``grid`` is the *canonical* grid name (None = identity) — resolve to
    a live object with ``grids.get_grid``; ``scheme`` the canonical
    scheme name.  `repro.core.rounding.parse_spec` lifts this to a
    :class:`~repro.core.rounding.RoundingSpec`.
    """

    grid: Optional[str]
    scheme: str = "rn"
    eps: float = 0.0
    rand_bits: int = 32
    overflow: str = "saturate"

    @property
    def is_identity(self) -> bool:
        return self.grid is None


IDENTITY_NAMES = ("fp32", "none")

_EPS_RE = re.compile(r"^e(\d+(?:\.\d+)?)$")
_RBITS_RE = re.compile(r"^r(\d+)$")


def parse_spec_name(name: str) -> ParsedSpec:
    """Parse one canonical ``<grid>-<scheme>[-e..][-r..][-inf]`` name."""
    if not isinstance(name, str) or not name:
        raise ValueError(f"spec name must be a non-empty string, got {name!r}")
    if name in IDENTITY_NAMES:
        return ParsedSpec(None)
    tokens = name.split("-")
    if len(tokens) < 2:
        raise ValueError(
            f"bad spec name {name!r}: expected '<grid>-<scheme>[-e<eps>]"
            f"[-r<bits>][-inf]' (or {'/'.join(IDENTITY_NAMES)})")
    grid = _grids.get_grid(tokens[0]).name
    # a scheme may be spelled with a dash ("sr-bittrick"): greedily try
    # the two-token join first, then fall back to the single token.
    rest = 2
    if len(tokens) > 2 and _ALIASES.get("-".join(tokens[1:3])) in _SCHEMES:
        scheme = get_scheme("-".join(tokens[1:3]))
        rest = 3
    else:
        scheme = get_scheme(tokens[1])
    eps, rand_bits, overflow = scheme.default_eps, scheme.default_rand_bits, \
        "saturate"
    for tok in tokens[rest:]:
        m = _EPS_RE.match(tok)
        if m:
            eps = float(m.group(1))
            continue
        m = _RBITS_RE.match(tok)
        if m:
            rand_bits = int(m.group(1))
            if rand_bits not in RAND_BITS_CHOICES:
                raise ValueError(f"{name!r}: rand_bits must be one of "
                                 f"{RAND_BITS_CHOICES}")
            continue
        if tok == "inf":
            overflow = "inf"
            continue
        raise ValueError(f"bad spec-name token {tok!r} in {name!r} "
                         "(expected e<eps>, r<bits> or inf)")
    return ParsedSpec(grid, scheme.name, eps, rand_bits, overflow)


def format_spec_name(grid: Optional[str], scheme: str = "rn",
                     eps: float = 0.0, rand_bits: int = 32,
                     overflow: str = "saturate") -> str:
    """Inverse of :func:`parse_spec_name` (canonical form; defaults
    elided so ``parse(format(...)) == parse(name)`` round-trips)."""
    if grid is None:
        return "fp32"
    s = get_scheme(scheme)
    out = f"{_grids.get_grid(grid).name}-{s.name}"
    if eps != s.default_eps:
        out += f"-e{eps:g}"
    if rand_bits != s.default_rand_bits:
        out += f"-r{rand_bits}"
    if overflow == "inf":
        out += "-inf"
    return out


def validate_spec_name(name: str) -> ParsedSpec:
    """Parse-or-raise, for import-time validation of name tables
    (`health/watchdog`'s precision ladder)."""
    return parse_spec_name(name)
