"""Shared plumbing for the quantized optimizers.

Per-leaf, per-step PRNG derivation: every parameter leaf gets an independent
key folded from (base_key, step, leaf_index) so that (a) rounding noise is
i.i.d. across parameters and steps, as the paper's analysis assumes, and
(b) the whole optimizer step is a deterministic function of the checkpointed
(key, step) — checkpoint/restart is bit-exact.

Three parameter-update paths, selected by the optimizer's ``update_path``:

* ``"jnp"``       — per-leaf pure-jnp chain (shards trivially under pjit;
                    the historical default and the cross-path reference);
* ``"fused"``     — ONE Pallas kernel over the flattened tree with
                    in-kernel randomness (12 B/elt; the TPU hot path);
* ``"fused_bits"``— same single kernel fed explicit random-bits operands
                    (24 B/elt; bit-exact vs the jnp oracle on the
                    concatenated vector — the audit mode).
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.gd import GDRounding, _resolve_v
from repro.core.rounding import RoundingSpec

UPDATE_PATHS = ("jnp", "fused", "fused_bits")


def leaf_keys(base_key, step, tree):
    """One key per leaf, folded from (base_key, step, leaf_idx)."""
    leaves = jax.tree_util.tree_leaves(tree)
    stepped = jax.random.fold_in(base_key, step)
    keys = [jax.random.fold_in(stepped, i) for i in range(len(leaves))]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(tree), keys)


def rounded_param_update(x, g, t, cfg: GDRounding, key):
    """The paper's eq.-8 parameter update for one leaf (pure-jnp path).

    This is semantically identical to kernels.fused_update (which is the
    TPU hot path); the jnp form is used under pjit where the elementwise
    chain shards trivially.
    """
    k1, k2, k3 = jax.random.split(key, 3)
    g_hat = cfg.grad(g, key=k1, v=_resolve_v(cfg.grad_v, g, x))
    upd = cfg.mul(jnp.float32(t) * g_hat, key=k2,
                  v=_resolve_v(cfg.mul_v, g_hat, x))
    z = x - upd
    return cfg.sub(z, key=k3, v=_resolve_v(cfg.sub_v, g_hat, x))


def round_state(spec: RoundingSpec, x, key):
    """Round an optimizer-state leaf onto its storage grid."""
    if spec.is_identity:
        return x
    return spec(x, key=key)


def tree_rounded_update(params, grads, t, cfg: GDRounding, key, step,
                        *, update_path: str = "jnp",
                        interpret: Optional[bool] = None):
    """Eq.-8 rounded update over a whole parameter pytree.

    Dispatches between the per-leaf jnp path and the whole-tree fused
    kernel (one ``pallas_call`` regardless of leaf count; see
    kernels/tree_update.py).
    """
    if update_path == "jnp":
        keys = leaf_keys(key, step, params)
        return jax.tree.map(
            lambda p, g, k: rounded_param_update(p, g, t, cfg, k),
            params, grads, keys)
    if update_path not in ("fused", "fused_bits"):
        raise ValueError(f"unknown update_path {update_path!r}; "
                         f"known: {UPDATE_PATHS}")
    # lazy import: keeps Pallas out of the optimizer's import graph unless
    # a kernel path is actually selected
    from repro.kernels.tree_update import fused_tree_update
    mode = "prng" if update_path == "fused" else "bits"

    def run(p, g, k, s):
        return fused_tree_update(p, g, t, cfg, k, s, mode=mode,
                                 interpret=interpret)

    # Under an ambient mesh the whole-tree pallas_call must not be handed
    # sharded operands: GSPMD has no partitioning rule for it and would
    # feed local shards into a kernel that indexes the global flat tree.
    # Run it inside a replicated shard_map instead — every participant
    # gathers the tree and computes the identical update (the counter-
    # keyed PRNG makes this bitwise equal to the single-device step).
    from repro.dist.sharding import _axes
    ax = _axes()
    if ax.active:
        from jax.sharding import PartitionSpec as P
        from repro.dist import compat
        pspec = jax.tree.map(lambda _: P(), params)
        return compat.shard_map(
            run, mesh=ax.mesh,
            in_specs=(pspec, pspec, P(), P()), out_specs=pspec,
            check_vma=False)(params, grads, key, step)
    return run(params, grads, key, step)


def tree_rounded_adam_update(params, grads, m, v, scal, cfg: GDRounding,
                             key, step, *, m_spec, v_spec, b1: float,
                             b2: float, packed: bool, cm=None, cv=None,
                             interpret: Optional[bool] = None):
    """Fully-fused QAdam step over a pytree (kernels/tree_update.py),
    with the same replicated-shard_map treatment as tree_rounded_update
    under an ambient mesh.  ``m``/``v`` (and ``cm``/``cv``) are flat
    carries; ``scal`` the (5,) [t, c1, c2, eps, wd] vector.  Returns
    ``(params⁺, m', v', cm', cv')`` (``cm'``/``cv'`` None when
    uncompensated)."""
    from repro.kernels.tree_update import fused_tree_adam_update
    kahan = cm is not None

    def run(p, g, m_, v_, s_, k, st, *comp):
        cm_, cv_ = comp if comp else (None, None)
        p2, m2, v2, cm2, cv2 = fused_tree_adam_update(
            p, g, m_, v_, s_, cfg, k, st, m_spec=m_spec, v_spec=v_spec,
            b1=b1, b2=b2, packed=packed, cm=cm_, cv=cv_,
            interpret=interpret)
        return (p2, m2, v2, cm2, cv2) if kahan else (p2, m2, v2)

    args = (params, grads, m, v, scal, key, step) \
        + ((cm, cv) if kahan else ())
    from repro.dist.sharding import _axes
    ax = _axes()
    if ax.active:
        from jax.sharding import PartitionSpec as P
        from repro.dist import compat
        pspec = jax.tree.map(lambda _: P(), params)
        in_specs = (pspec, pspec, P(), P(), P(), P(), P()) \
            + ((P(), P()) if kahan else ())
        out_specs = (pspec, P(), P()) + ((P(), P()) if kahan else ())
        res = compat.shard_map(run, mesh=ax.mesh, in_specs=in_specs,
                               out_specs=out_specs,
                               check_vma=False)(*args)
    else:
        res = run(*args)
    return res if kahan else res + (None, None)
