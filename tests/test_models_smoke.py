"""Per-architecture smoke tests: REDUCED configs of the same family, one
forward/train step + one decode step on CPU, asserting shapes and finiteness
(as required by the assignment)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, reduced
from repro.models import build_model

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _batch(cfg):
    tk, vk = jax.random.split(KEY)
    batch = {}
    s_text = S
    if cfg.frontend == "vision":
        s_text = S - cfg.frontend_len
        batch["vision_embeds"] = jax.random.normal(
            vk, (B, cfg.frontend_len, cfg.d_model), jnp.float32) * 0.02
    if cfg.frontend == "audio":
        batch["src_embeds"] = jax.random.normal(
            vk, (B, S, cfg.d_model), jnp.float32) * 0.02
    batch["tokens"] = jax.random.randint(tk, (B, s_text), 0, cfg.vocab_size)
    batch["labels"] = jax.random.randint(tk, (B, s_text), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_loss_and_grad(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    loss, metrics = model.loss_fn(params, batch, rng=KEY)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), arch
    # gradient flows through every block type
    g = jax.grad(lambda p: model.loss_fn(p, batch, rng=KEY)[0])(params)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(x))
                      for x in jax.tree_util.tree_leaves(g)))
    assert np.isfinite(float(gn)) and float(gn) > 0, arch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_step(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(KEY)
    caches = model.init_decode_cache(batch=B, max_len=32)
    enc_out = None
    if cfg.encoder_layers:
        enc_out = jax.random.normal(KEY, (B, 8, cfg.d_model),
                                    jnp.float32) * 0.02
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, caches = model.decode_step(params, caches, tok, 0,
                                       enc_out=enc_out)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32))), arch
    # a second step advances lengths
    logits2, caches2 = model.decode_step(params, caches, tok, 1,
                                         enc_out=enc_out)
    for t, c in caches2.items():
        if hasattr(c, "length"):
            assert int(np.asarray(c.length).max()) == 2


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_emits_caches(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    batch.pop("labels")
    logits, caches = model.prefill(params, batch, rng=KEY)
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab_size
    assert caches, arch
    for t, c in caches.items():
        for leaf in jax.tree_util.tree_leaves(c):
            assert np.all(np.isfinite(np.asarray(leaf, np.float32))), (arch, t)


def test_decode_matches_forward_dense():
    """Teacher-forced decode must reproduce the full-sequence forward
    (dense GQA path; validates cache correctness)."""
    cfg = reduced(get_config("tinyllama-1.1b"))
    model = build_model(cfg)
    params = model.init(KEY)
    toks = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)
    h, _, _ = model.hidden_states(params, {"tokens": toks})
    full_logits = model._logits(params, h)  # (1, 8, V)

    caches = model.init_decode_cache(batch=1, max_len=8)
    outs = []
    for t in range(8):
        lg, caches = model.decode_step(params, caches, toks[:, t:t + 1], t)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full_logits, np.float32),
                               np.asarray(dec_logits, np.float32),
                               rtol=0.05, atol=0.05)


def test_decode_matches_forward_ssm():
    """Same equivalence for the Mamba2 path (chunked-scan vs step)."""
    cfg = reduced(get_config("zamba2-1.2b"))
    model = build_model(cfg)
    params = model.init(KEY)
    toks = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)
    h, _, _ = model.hidden_states(params, {"tokens": toks})
    full_logits = model._logits(params, h)

    caches = model.init_decode_cache(batch=1, max_len=8)
    outs = []
    for t in range(8):
        lg, caches = model.decode_step(params, caches, toks[:, t:t + 1], t)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full_logits, np.float32),
                               np.asarray(dec_logits, np.float32),
                               rtol=0.05, atol=0.05)


def test_decode_matches_forward_rwkv():
    """And for RWKV6 (chunked wkv vs one-step recurrence)."""
    cfg = reduced(get_config("rwkv6-7b"))
    model = build_model(cfg)
    params = model.init(KEY)
    toks = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)
    h, _, _ = model.hidden_states(params, {"tokens": toks})
    full_logits = model._logits(params, h)

    caches = model.init_decode_cache(batch=1, max_len=8)
    outs = []
    for t in range(8):
        lg, caches = model.decode_step(params, caches, toks[:, t:t + 1], t)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full_logits, np.float32),
                               np.asarray(dec_logits, np.float32),
                               rtol=0.05, atol=0.05)


def test_param_count_estimates_track_actuals():
    """ModelConfig.param_count_estimate within 2x of the true count on the
    reduced configs (the estimate feeds MODEL_FLOPS in §Roofline)."""
    for arch in ("tinyllama-1.1b", "gemma-7b", "qwen3-moe-30b-a3b"):
        cfg = reduced(get_config(arch))
        model = build_model(cfg)
        params = model.init(KEY)
        actual = model.param_count(params)
        est = cfg.param_count_estimate
        assert 0.4 < est / actual < 2.5, (arch, est, actual)


def test_moe_aux_loss_present():
    cfg = reduced(get_config("qwen3-moe-30b-a3b"))
    model = build_model(cfg)
    params = model.init(KEY)
    _, metrics = model.loss_fn(params, _batch(cfg), rng=KEY)
    assert float(metrics["moe_aux"]) > 0


def test_mla_absorbed_decode_matches_naive():
    """The absorbed-matmul MLA decode (§Perf optimization) must be
    numerically equivalent to the naive decompress-then-attend path."""
    import dataclasses
    cfg = reduced(get_config("deepseek-v2-236b"))
    cfg_abs = dataclasses.replace(
        cfg, mla=dataclasses.replace(cfg.mla, absorb=True))
    m1, m2 = build_model(cfg), build_model(cfg_abs)
    params = m1.init(KEY)
    toks = jax.random.randint(KEY, (2, 6), 0, cfg.vocab_size)
    c1 = m1.init_decode_cache(2, 8)
    c2 = m2.init_decode_cache(2, 8)
    for t in range(6):
        l1, c1 = m1.decode_step(params, c1, toks[:, t:t + 1], t)
        l2, c2 = m2.decode_step(params, c2, toks[:, t:t + 1], t)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32),
                               rtol=0.05, atol=0.05)
