"""Numeric-health + fault-tolerance subsystem.

Three layers, threaded through the training stack (ISSUE 6 tentpole):

* ``monitor``  — cheap in-step telemetry (jnp reductions inside the jit'd
  train step): deadband fraction (the paper's §3.2 RN-stagnation predicate
  lifted from the toy GD path to arbitrary pytrees), saturation/underflow
  counts against the active format's limits, grad/update norms, and
  non-finite flags, carried as a ``HealthState`` in the train-step carry.
* ``watchdog`` — a host-side policy state machine consuming the telemetry:
  sustained deadband escalates the run along a precision ladder
  (binary8-rn → binary8-sr → e4m3-sr → bf16-sr → fp32), sustained
  non-finite gradients trigger a checkpoint rollback; every transition is
  logged with step + trigger so a run explains its own precision history.
* ``inject``   — deterministic, seed-keyed fault schedules (bit flips,
  NaN/Inf injection, simulated preemption / SIGKILL, checkpoint
  corruption) for chaos testing the two layers above.
"""
from repro.health.monitor import (HealthConfig, HealthState,
                                  health_metrics, init_health_state,
                                  observe_health, resolve_health,
                                  update_health)
from repro.health.watchdog import (DEFAULT_LADDER, Escalate, LEVELS,
                                   PrecisionLevel, Rollback, Watchdog,
                                   WatchdogConfig, get_level, initial_level,
                                   rounding_for_level, validate_ladder)
from repro.health.inject import (FaultEvent, FaultInjector,
                                 corrupt_checkpoint, flip_bit,
                                 parse_fault_schedule)

__all__ = [
    "HealthConfig", "HealthState", "health_metrics", "init_health_state",
    "observe_health", "resolve_health", "update_health",
    "DEFAULT_LADDER", "Escalate", "LEVELS", "PrecisionLevel", "Rollback",
    "Watchdog", "WatchdogConfig", "get_level", "initial_level",
    "rounding_for_level", "validate_ladder",
    "FaultEvent", "FaultInjector", "corrupt_checkpoint", "flip_bit",
    "parse_fault_schedule",
]
